//! Constraint expression evaluator.
//!
//! The annotation language (and `aot.py`'s manifest) declares parameter
//! constraints as strings like `"block_size % unroll == 0"` or
//! `"tile_m <= m && tile_n <= n"`.  The grammar is shared with the python
//! side (model.py rewrites `&&`/`||` to `and`/`or` and evaluates the same
//! strings), so the two layers can never disagree about validity.
//!
//! Grammar (C-style precedence):
//! ```text
//! expr  := or
//! or    := and ("||" and)*
//! and   := cmp ("&&" cmp)*
//! cmp   := sum (("=="|"!="|"<="|">="|"<"|">") sum)?
//! sum   := term (("+"|"-") term)*
//! term  := unary (("*"|"/"|"%") unary)*
//! unary := ("-"|"!") unary | atom
//! atom  := integer | identifier | "(" expr ")"
//! ```
//! Semantics: 64-bit integer arithmetic; comparisons and logic produce
//! 0/1; division/modulo by zero and unknown identifiers are runtime
//! errors (never panics).

use std::collections::BTreeMap;

/// Evaluation environment: dims and parameter values by name.
pub type Env = BTreeMap<String, i64>;

/// Errors from parsing or evaluating a constraint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConstraintError {
    /// The source string is not a valid expression.
    Parse {
        /// Byte offset of the failure.
        offset: usize,
        /// What was expected.
        message: String,
    },
    /// An identifier was neither a parameter nor a workload dim.
    UnknownIdent(String),
    /// Division or modulo by zero during evaluation.
    DivByZero,
    /// 64-bit integer overflow during evaluation.
    Overflow,
}

impl std::fmt::Display for ConstraintError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConstraintError::Parse { offset, message } => {
                write!(f, "constraint parse error at {offset}: {message}")
            }
            ConstraintError::UnknownIdent(id) => write!(f, "unknown identifier: {id}"),
            ConstraintError::DivByZero => write!(f, "division by zero"),
            ConstraintError::Overflow => write!(f, "integer overflow"),
        }
    }
}

impl std::error::Error for ConstraintError {}

/// A parsed constraint expression (reusable across evaluations).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expr {
    /// Integer literal.
    Int(i64),
    /// Parameter or dim reference.
    Ident(String),
    /// Unary operator application.
    Unary(UnaryOp, Box<Expr>),
    /// Binary operator application.
    Binary(BinOp, Box<Expr>, Box<Expr>),
}

/// Unary operators of the constraint grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryOp {
    /// Arithmetic negation (`-x`).
    Neg,
    /// Logical not (`!x`, 0/1 semantics).
    Not,
}

/// Binary operators of the constraint grammar (C-style precedence).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    /// `+`
    Add,
    /// `-`
    Sub,
    /// `*`
    Mul,
    /// `/` (integer division; zero divisor errors)
    Div,
    /// `%` (zero divisor errors)
    Mod,
    /// `==`
    Eq,
    /// `!=`
    Ne,
    /// `<=`
    Le,
    /// `>=`
    Ge,
    /// `<`
    Lt,
    /// `>`
    Gt,
    /// `&&` (0/1 semantics)
    And,
    /// `||` (0/1 semantics)
    Or,
}

impl Expr {
    /// Parse an expression string.
    pub fn parse(src: &str) -> Result<Expr, ConstraintError> {
        let tokens = tokenize(src)?;
        let mut p = TokParser { tokens: &tokens, pos: 0, src_len: src.len() };
        let e = p.or_expr()?;
        if p.pos != p.tokens.len() {
            return Err(ConstraintError::Parse {
                offset: p.tokens[p.pos].1,
                message: "trailing tokens".into(),
            });
        }
        Ok(e)
    }

    /// Evaluate to an integer (booleans are 0/1).
    pub fn eval(&self, env: &Env) -> Result<i64, ConstraintError> {
        match self {
            Expr::Int(v) => Ok(*v),
            Expr::Ident(name) => env
                .get(name)
                .copied()
                .ok_or_else(|| ConstraintError::UnknownIdent(name.clone())),
            Expr::Unary(op, e) => {
                let v = e.eval(env)?;
                Ok(match op {
                    UnaryOp::Neg => v.checked_neg().ok_or(ConstraintError::Overflow)?,
                    UnaryOp::Not => (v == 0) as i64,
                })
            }
            Expr::Binary(op, a, b) => {
                // Short-circuit logic ops.
                match op {
                    BinOp::And => {
                        return Ok(if a.eval(env)? != 0 && b.eval(env)? != 0 { 1 } else { 0 })
                    }
                    BinOp::Or => {
                        return Ok(if a.eval(env)? != 0 || b.eval(env)? != 0 { 1 } else { 0 })
                    }
                    _ => {}
                }
                let x = a.eval(env)?;
                let y = b.eval(env)?;
                Ok(match op {
                    BinOp::Add => x.checked_add(y).ok_or(ConstraintError::Overflow)?,
                    BinOp::Sub => x.checked_sub(y).ok_or(ConstraintError::Overflow)?,
                    BinOp::Mul => x.checked_mul(y).ok_or(ConstraintError::Overflow)?,
                    BinOp::Div => {
                        if y == 0 {
                            return Err(ConstraintError::DivByZero);
                        }
                        x.checked_div(y).ok_or(ConstraintError::Overflow)?
                    }
                    BinOp::Mod => {
                        if y == 0 {
                            return Err(ConstraintError::DivByZero);
                        }
                        x.checked_rem(y).ok_or(ConstraintError::Overflow)?
                    }
                    BinOp::Eq => (x == y) as i64,
                    BinOp::Ne => (x != y) as i64,
                    BinOp::Le => (x <= y) as i64,
                    BinOp::Ge => (x >= y) as i64,
                    BinOp::Lt => (x < y) as i64,
                    BinOp::Gt => (x > y) as i64,
                    BinOp::And | BinOp::Or => unreachable!(),
                })
            }
        }
    }

    /// Evaluate as a boolean (non-zero is true).
    pub fn eval_bool(&self, env: &Env) -> Result<bool, ConstraintError> {
        Ok(self.eval(env)? != 0)
    }

    /// All identifiers referenced by the expression.
    pub fn idents(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.collect_idents(&mut out);
        out.sort();
        out.dedup();
        out
    }

    fn collect_idents(&self, out: &mut Vec<String>) {
        match self {
            Expr::Int(_) => {}
            Expr::Ident(n) => out.push(n.clone()),
            Expr::Unary(_, e) => e.collect_idents(out),
            Expr::Binary(_, a, b) => {
                a.collect_idents(out);
                b.collect_idents(out);
            }
        }
    }
}

/// One-shot convenience: parse and evaluate as bool.
pub fn check(src: &str, env: &Env) -> Result<bool, ConstraintError> {
    Expr::parse(src)?.eval_bool(env)
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Int(i64),
    Ident(String),
    Op(&'static str),
    LParen,
    RParen,
}

fn tokenize(src: &str) -> Result<Vec<(Tok, usize)>, ConstraintError> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        match b {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'(' => {
                toks.push((Tok::LParen, i));
                i += 1;
            }
            b')' => {
                toks.push((Tok::RParen, i));
                i += 1;
            }
            b'0'..=b'9' => {
                let start = i;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    i += 1;
                }
                let text = &src[start..i];
                let v = text.parse::<i64>().map_err(|_| ConstraintError::Parse {
                    offset: start,
                    message: "integer too large".into(),
                })?;
                toks.push((Tok::Int(v), start));
            }
            b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                let start = i;
                while i < bytes.len()
                    && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                }
                toks.push((Tok::Ident(src[start..i].to_string()), start));
            }
            _ => {
                // Two-char operators first.
                let two = if i + 1 < bytes.len() { &src[i..i + 2] } else { "" };
                let op2 = match two {
                    "==" => Some("=="),
                    "!=" => Some("!="),
                    "<=" => Some("<="),
                    ">=" => Some(">="),
                    "&&" => Some("&&"),
                    "||" => Some("||"),
                    _ => None,
                };
                if let Some(op) = op2 {
                    toks.push((Tok::Op(op), i));
                    i += 2;
                    continue;
                }
                let op1 = match b {
                    b'+' => Some("+"),
                    b'-' => Some("-"),
                    b'*' => Some("*"),
                    b'/' => Some("/"),
                    b'%' => Some("%"),
                    b'<' => Some("<"),
                    b'>' => Some(">"),
                    b'!' => Some("!"),
                    _ => None,
                };
                match op1 {
                    Some(op) => {
                        toks.push((Tok::Op(op), i));
                        i += 1;
                    }
                    None => {
                        return Err(ConstraintError::Parse {
                            offset: i,
                            message: format!("unexpected character '{}'", b as char),
                        })
                    }
                }
            }
        }
    }
    Ok(toks)
}

struct TokParser<'a> {
    tokens: &'a [(Tok, usize)],
    pos: usize,
    src_len: usize,
}

impl<'a> TokParser<'a> {
    fn err(&self, message: &str) -> ConstraintError {
        let offset = self
            .tokens
            .get(self.pos)
            .map(|(_, o)| *o)
            .unwrap_or(self.src_len);
        ConstraintError::Parse { offset, message: message.into() }
    }

    fn peek_op(&self) -> Option<&'static str> {
        match self.tokens.get(self.pos) {
            Some((Tok::Op(op), _)) => Some(op),
            _ => None,
        }
    }

    fn take_op(&mut self, ops: &[&'static str]) -> Option<&'static str> {
        if let Some(op) = self.peek_op() {
            if ops.contains(&op) {
                self.pos += 1;
                return Some(op);
            }
        }
        None
    }

    fn or_expr(&mut self) -> Result<Expr, ConstraintError> {
        let mut lhs = self.and_expr()?;
        while self.take_op(&["||"]).is_some() {
            let rhs = self.and_expr()?;
            lhs = Expr::Binary(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, ConstraintError> {
        let mut lhs = self.cmp_expr()?;
        while self.take_op(&["&&"]).is_some() {
            let rhs = self.cmp_expr()?;
            lhs = Expr::Binary(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr, ConstraintError> {
        let lhs = self.sum_expr()?;
        if let Some(op) = self.take_op(&["==", "!=", "<=", ">=", "<", ">"]) {
            let rhs = self.sum_expr()?;
            let bop = match op {
                "==" => BinOp::Eq,
                "!=" => BinOp::Ne,
                "<=" => BinOp::Le,
                ">=" => BinOp::Ge,
                "<" => BinOp::Lt,
                ">" => BinOp::Gt,
                _ => unreachable!(),
            };
            return Ok(Expr::Binary(bop, Box::new(lhs), Box::new(rhs)));
        }
        Ok(lhs)
    }

    fn sum_expr(&mut self) -> Result<Expr, ConstraintError> {
        let mut lhs = self.term_expr()?;
        while let Some(op) = self.take_op(&["+", "-"]) {
            let rhs = self.term_expr()?;
            let bop = if op == "+" { BinOp::Add } else { BinOp::Sub };
            lhs = Expr::Binary(bop, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn term_expr(&mut self) -> Result<Expr, ConstraintError> {
        let mut lhs = self.unary_expr()?;
        while let Some(op) = self.take_op(&["*", "/", "%"]) {
            let rhs = self.unary_expr()?;
            let bop = match op {
                "*" => BinOp::Mul,
                "/" => BinOp::Div,
                _ => BinOp::Mod,
            };
            lhs = Expr::Binary(bop, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr, ConstraintError> {
        if self.take_op(&["-"]).is_some() {
            return Ok(Expr::Unary(UnaryOp::Neg, Box::new(self.unary_expr()?)));
        }
        if self.take_op(&["!"]).is_some() {
            return Ok(Expr::Unary(UnaryOp::Not, Box::new(self.unary_expr()?)));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Expr, ConstraintError> {
        match self.tokens.get(self.pos) {
            Some((Tok::Int(v), _)) => {
                self.pos += 1;
                Ok(Expr::Int(*v))
            }
            Some((Tok::Ident(name), _)) => {
                self.pos += 1;
                Ok(Expr::Ident(name.clone()))
            }
            Some((Tok::LParen, _)) => {
                self.pos += 1;
                let e = self.or_expr()?;
                match self.tokens.get(self.pos) {
                    Some((Tok::RParen, _)) => {
                        self.pos += 1;
                        Ok(e)
                    }
                    _ => Err(self.err("expected ')'")),
                }
            }
            _ => Err(self.err("expected integer, identifier, or '('")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(pairs: &[(&str, i64)]) -> Env {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn arithmetic_precedence() {
        let e = env(&[]);
        assert_eq!(Expr::parse("2 + 3 * 4").unwrap().eval(&e).unwrap(), 14);
        assert_eq!(Expr::parse("(2 + 3) * 4").unwrap().eval(&e).unwrap(), 20);
        assert_eq!(Expr::parse("10 - 4 - 3").unwrap().eval(&e).unwrap(), 3);
        assert_eq!(Expr::parse("17 % 5").unwrap().eval(&e).unwrap(), 2);
        assert_eq!(Expr::parse("17 / 5").unwrap().eval(&e).unwrap(), 3);
        assert_eq!(Expr::parse("-3 + 1").unwrap().eval(&e).unwrap(), -2);
    }

    #[test]
    fn comparisons_and_logic() {
        let e = env(&[("n", 4096), ("block_size", 1024), ("unroll", 4)]);
        assert!(check("block_size <= n", &e).unwrap());
        assert!(check("block_size % unroll == 0", &e).unwrap());
        assert!(!check("block_size > n", &e).unwrap());
        assert!(check("block_size <= n && unroll != 3", &e).unwrap());
        assert!(check("block_size > n || unroll == 4", &e).unwrap());
        assert!(check("!(block_size > n)", &e).unwrap());
    }

    #[test]
    fn manifest_constraints_evaluate() {
        // The exact strings aot.py writes.
        let good = env(&[("n", 65536), ("block_size", 4096), ("unroll", 2)]);
        let bad = env(&[("n", 4096), ("block_size", 16384), ("unroll", 2)]);
        for c in ["block_size <= n", "block_size % unroll == 0"] {
            assert!(check(c, &good).unwrap(), "{c}");
        }
        assert!(!check("block_size <= n", &bad).unwrap());
    }

    #[test]
    fn unknown_identifier_errors() {
        let e = env(&[]);
        assert_eq!(
            check("missing == 1", &e),
            Err(ConstraintError::UnknownIdent("missing".into()))
        );
    }

    #[test]
    fn division_by_zero_errors() {
        let e = env(&[("z", 0)]);
        assert_eq!(check("1 / z == 0", &e), Err(ConstraintError::DivByZero));
        assert_eq!(check("1 % z == 0", &e), Err(ConstraintError::DivByZero));
    }

    #[test]
    fn overflow_errors_not_panics() {
        let e = env(&[]);
        let big = format!("{} * 2", i64::MAX);
        assert_eq!(check(&big, &e), Err(ConstraintError::Overflow));
        let neg = format!("-({}) - 2", i64::MAX);
        assert!(matches!(check(&neg, &e), Err(ConstraintError::Overflow)));
    }

    #[test]
    fn parse_errors_carry_offsets() {
        match Expr::parse("1 + ") {
            Err(ConstraintError::Parse { offset, .. }) => assert_eq!(offset, 4),
            other => panic!("{other:?}"),
        }
        assert!(Expr::parse("").is_err());
        assert!(Expr::parse("(1").is_err());
        assert!(Expr::parse("1 @ 2").is_err());
        assert!(Expr::parse("1 2").is_err());
    }

    #[test]
    fn short_circuit_avoids_rhs_errors() {
        let e = env(&[("z", 0)]);
        // RHS divides by zero but LHS decides.
        assert!(check("1 == 1 || 1 / z == 0", &e).unwrap());
        assert!(!check("1 == 0 && 1 / z == 0", &e).unwrap());
    }

    #[test]
    fn idents_collected_sorted_unique() {
        let e = Expr::parse("a + b * a <= c && b > 0").unwrap();
        assert_eq!(e.idents(), vec!["a".to_string(), "b".into(), "c".into()]);
    }

    #[test]
    fn chained_comparison_is_rejected() {
        // cmp is non-associative by design: "a < b < c" must not parse.
        assert!(Expr::parse("1 < 2 < 3").is_err());
    }
}
