//! The autotuning coordinator — the paper's system contribution.
//!
//! Pipeline (paper §2): annotation → variant space ([`spec`], parsed
//! from [`annotation`] blocks or the AOT manifest) → empirical search
//! ([`search`]) with compiled-variant measurement ([`measure`]) and
//! reference-output gating ([`selection`]) → platform-keyed persistence
//! ([`perfdb`], [`platform`]) → deployment.  [`tuner`] wires the stages
//! together over the [`crate::runtime`] layer.

pub mod annotation;
pub mod constraint;
pub mod ledger;
pub mod measure;
pub mod perfdb;
pub mod platform;
pub mod portfolio;
pub mod search;
pub mod selection;
pub mod spec;
pub mod tuner;
