//! Tuning specification: the parameter space a search strategy explores.
//!
//! A [`TuningSpec`] is the runtime form of the paper's annotation block:
//! named parameters with finite value domains, plus constraint strings
//! over parameters *and* workload dimensions.  It exposes the operations
//! every search strategy needs: enumeration, validity checking, random
//! sampling, index encoding, and neighborhood moves.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::runtime::registry::{KernelEntry, ParamDef, Workload};
use crate::util::rng::Rng;

use super::constraint::{Env, Expr};

/// A concrete parameter assignment (param name → value).
pub type Config = BTreeMap<String, i64>;

/// The searchable space for one (kernel, workload) pair.
#[derive(Debug, Clone)]
pub struct TuningSpec {
    /// Kernel family being tuned.
    pub kernel: String,
    /// Workload tag being tuned.
    pub tag: String,
    /// Parameter schemas, in declaration order (id/enumeration order).
    pub params: Vec<ParamDef>,
    /// Workload dims visible to constraints.
    pub dims: BTreeMap<String, i64>,
    constraints: Vec<(String, Expr)>,
}

impl TuningSpec {
    /// Build from manifest entries (parses the constraint strings once).
    pub fn from_manifest(kernel: &KernelEntry, workload: &Workload) -> Result<TuningSpec> {
        let constraints = kernel
            .constraints
            .iter()
            .map(|src| {
                Expr::parse(src)
                    .map(|e| (src.clone(), e))
                    .map_err(|e| anyhow::anyhow!("constraint `{src}`: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TuningSpec {
            kernel: kernel.name.clone(),
            tag: workload.tag.clone(),
            params: kernel.params.clone(),
            dims: workload.dims.clone(),
            constraints,
        })
    }

    /// Build directly (annotation parser, tests).
    pub fn new(
        kernel: impl Into<String>,
        tag: impl Into<String>,
        params: Vec<ParamDef>,
        constraint_srcs: &[String],
        dims: BTreeMap<String, i64>,
    ) -> Result<TuningSpec> {
        let constraints = constraint_srcs
            .iter()
            .map(|src| {
                Expr::parse(src)
                    .map(|e| (src.clone(), e))
                    .map_err(|e| anyhow::anyhow!("constraint `{src}`: {e}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TuningSpec {
            kernel: kernel.into(),
            tag: tag.into(),
            params,
            dims,
            constraints,
        })
    }

    /// The constraint source strings, in declaration order.
    pub fn constraint_srcs(&self) -> Vec<&str> {
        self.constraints.iter().map(|(s, _)| s.as_str()).collect()
    }

    /// Total size of the raw (unconstrained) cartesian space.
    pub fn raw_space_size(&self) -> usize {
        self.params.iter().map(|p| p.values.len().max(1)).product()
    }

    /// Is a config a complete, in-domain, constraint-satisfying point?
    pub fn is_valid(&self, config: &Config) -> bool {
        if config.len() != self.params.len() {
            return false;
        }
        for p in &self.params {
            match config.get(&p.name) {
                Some(v) if p.values.contains(v) => {}
                _ => return false,
            }
        }
        let env = self.env_for(config);
        self.constraints
            .iter()
            .all(|(_, e)| e.eval_bool(&env).unwrap_or(false))
    }

    fn env_for(&self, config: &Config) -> Env {
        let mut env: Env = self.dims.clone();
        for (k, v) in config {
            env.insert(k.clone(), *v);
        }
        env
    }

    /// Enumerate all *valid* configs in deterministic (lexicographic by
    /// declaration order) order — matches `model.Family.grid` in python.
    pub fn enumerate(&self) -> Vec<Config> {
        let mut out = Vec::new();
        let mut idx = vec![0usize; self.params.len()];
        if self.params.is_empty() {
            return out;
        }
        loop {
            let config = self.config_at(&idx);
            if self.is_valid(&config) {
                out.push(config);
            }
            // Odometer increment, last param fastest (python order).
            let mut i = self.params.len();
            loop {
                if i == 0 {
                    return out;
                }
                i -= 1;
                idx[i] += 1;
                if idx[i] < self.params[i].values.len() {
                    break;
                }
                idx[i] = 0;
            }
        }
    }

    /// Config from a per-parameter index vector.
    pub fn config_at(&self, idx: &[usize]) -> Config {
        assert_eq!(idx.len(), self.params.len());
        self.params
            .iter()
            .zip(idx)
            .map(|(p, &i)| (p.name.clone(), p.values[i]))
            .collect()
    }

    /// Index vector for a config (`None` if any value is out of domain).
    pub fn index_of(&self, config: &Config) -> Option<Vec<usize>> {
        self.params
            .iter()
            .map(|p| {
                config
                    .get(&p.name)
                    .and_then(|v| p.values.iter().position(|x| x == v))
            })
            .collect()
    }

    /// Stable identifier matching `aot.py`'s variant ids (`b1024_u4`).
    pub fn config_id(&self, config: &Config) -> String {
        self.params
            .iter()
            .map(|p| format!("{}{}", p.abbrev, config.get(&p.name).copied().unwrap_or(-1)))
            .collect::<Vec<_>>()
            .join("_")
    }

    /// Uniform random *valid* config; `None` if none found within the
    /// attempt budget (pathologically tight constraints).
    pub fn random_config(&self, rng: &mut Rng, max_attempts: usize) -> Option<Config> {
        for _ in 0..max_attempts {
            let idx: Vec<usize> = self
                .params
                .iter()
                .map(|p| rng.gen_range(p.values.len()))
                .collect();
            let config = self.config_at(&idx);
            if self.is_valid(&config) {
                return Some(config);
            }
        }
        None
    }

    /// One-step neighbors: move each parameter one position up/down its
    /// (ordered) domain, keeping the others fixed.  Only valid configs
    /// are returned.  This is the move set for hill-climbing and
    /// annealing — value domains are ordered (powers of two), so
    /// adjacent indices are the natural "small step".
    pub fn neighbors(&self, config: &Config) -> Vec<Config> {
        let Some(idx) = self.index_of(config) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for (i, p) in self.params.iter().enumerate() {
            for delta in [-1i64, 1] {
                let j = idx[i] as i64 + delta;
                if j < 0 || j as usize >= p.values.len() {
                    continue;
                }
                let mut nidx = idx.clone();
                nidx[i] = j as usize;
                let cand = self.config_at(&nidx);
                if self.is_valid(&cand) {
                    out.push(cand);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TuningSpec {
        TuningSpec::new(
            "axpy",
            "n4096",
            vec![
                ParamDef {
                    name: "block_size".into(),
                    abbrev: "b".into(),
                    values: vec![256, 1024, 4096, 16384],
                },
                ParamDef { name: "unroll".into(), abbrev: "u".into(), values: vec![1, 2, 4] },
            ],
            &[
                "block_size <= n".to_string(),
                "block_size % unroll == 0".to_string(),
            ],
            [("n".to_string(), 4096i64)].into_iter().collect(),
        )
        .unwrap()
    }

    fn cfg(b: i64, u: i64) -> Config {
        [("block_size".to_string(), b), ("unroll".to_string(), u)]
            .into_iter()
            .collect()
    }

    #[test]
    fn enumerate_respects_constraints() {
        let s = spec();
        let all = s.enumerate();
        // 4 blocks x 3 unrolls = 12 raw; block 16384 > n=4096 pruned -> 9.
        assert_eq!(s.raw_space_size(), 12);
        assert_eq!(all.len(), 9);
        assert!(all.iter().all(|c| s.is_valid(c)));
        assert!(!all.iter().any(|c| c["block_size"] == 16384));
    }

    #[test]
    fn enumeration_order_is_declaration_order() {
        let s = spec();
        let all = s.enumerate();
        assert_eq!(all[0], cfg(256, 1));
        assert_eq!(all[1], cfg(256, 2));
        assert_eq!(all[2], cfg(256, 4));
        assert_eq!(all[3], cfg(1024, 1));
    }

    #[test]
    fn validity_edges() {
        let s = spec();
        assert!(s.is_valid(&cfg(4096, 4)));
        assert!(!s.is_valid(&cfg(16384, 1))); // violates block <= n
        assert!(!s.is_valid(&cfg(512, 1))); // 512 not in domain
        assert!(!s.is_valid(&cfg(256, 3))); // 3 not in domain
        let mut incomplete = Config::new();
        incomplete.insert("block_size".into(), 256);
        assert!(!s.is_valid(&incomplete));
        let mut extra = cfg(256, 1);
        extra.insert("bogus".into(), 1);
        assert!(!s.is_valid(&extra));
    }

    #[test]
    fn config_id_matches_aot_format() {
        let s = spec();
        assert_eq!(s.config_id(&cfg(1024, 4)), "b1024_u4");
    }

    #[test]
    fn index_round_trip() {
        let s = spec();
        for c in s.enumerate() {
            let idx = s.index_of(&c).unwrap();
            assert_eq!(s.config_at(&idx), c);
        }
        assert!(s.index_of(&cfg(512, 1)).is_none());
    }

    #[test]
    fn random_config_always_valid() {
        let s = spec();
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let c = s.random_config(&mut rng, 100).unwrap();
            assert!(s.is_valid(&c));
        }
    }

    #[test]
    fn neighbors_are_valid_one_step_moves() {
        let s = spec();
        let c = cfg(1024, 2);
        let ns = s.neighbors(&c);
        // block: 256 or 4096; unroll: 1 or 4 — all valid here.
        assert_eq!(ns.len(), 4);
        for n in &ns {
            assert!(s.is_valid(n));
            let differs = n
                .iter()
                .filter(|(k, v)| c.get(k.as_str()) != Some(v))
                .count();
            assert_eq!(differs, 1);
        }
    }

    #[test]
    fn neighbors_prune_invalid() {
        let s = spec();
        // 4096 is the top valid block; the up-neighbor 16384 violates
        // block <= n and must be pruned.
        let ns = s.neighbors(&cfg(4096, 1));
        assert!(ns.iter().all(|n| n["block_size"] != 16384));
    }
}
