//! # portatune
//!
//! Annotation-based software autotuning for sustainable performance
//! portability — a reproduction of Mametjanov & Norris (2013) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! - **Layer 1 (build time)**: parameterized Pallas kernels
//!   (`python/compile/kernels/`) — the schedule space the paper expressed
//!   as SIMD/CUDA pragmas.
//! - **Layer 2 (build time)**: JAX compute graphs (`python/compile/model.py`)
//!   lowered AOT to one HLO-text artifact per (kernel, workload, variant).
//! - **Layer 3 (this crate)**: the autotuner — empirical search over the
//!   pre-lowered variants with correctness gating against the reference
//!   implementation, platform fingerprinting, and a persistent
//!   performance database that makes the tuned configuration *portable*.
//!
//! ```no_run
//! use portatune::prelude::*;
//!
//! let runtime = Runtime::cpu()?;
//! let registry = Registry::open(runtime, "artifacts")?;
//! let tuner = Tuner::new(&registry);
//! let mut strategy = Exhaustive::new();
//! let outcome = tuner.tune("axpy", "n65536", &mut strategy, usize::MAX)?;
//! if let Some(best) = &outcome.best {
//!     println!("best {} speedup {:.2}x", best.config_id, outcome.speedup());
//! }
//! # anyhow::Ok(())
//! ```

#![warn(missing_docs)]

pub mod coordinator;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod util;
pub mod worker;
pub mod workload;

// Without the `xla-runtime` feature the real `xla` crate (which needs the
// xla_extension native library) is replaced by an API-compatible stub;
// runtime modules import `crate::xla` under the same cfg so either
// resolution compiles unchanged.
#[cfg(not(feature = "xla-runtime"))]
#[path = "runtime/xla_stub.rs"]
pub mod xla;

// With the feature on, the real bindings must be supplied by the user.
// If the next line fails to resolve, add
//   xla = { git = "https://github.com/LaurentMazare/xla-rs" }
// to rust/Cargo.toml — its comment explains why the dependency is not
// pre-declared.
#[cfg(feature = "xla-runtime")]
extern crate xla;

/// Everything a typical embedder needs.
pub mod prelude {
    pub use crate::coordinator::measure::{MeasureConfig, Measurement};
    pub use crate::coordinator::perfdb::{PerfDb, Shard, ShardedDb};
    pub use crate::coordinator::platform::Fingerprint;
    pub use crate::coordinator::portfolio::{CostMatrix, Portfolio, PortfolioItem};
    pub use crate::coordinator::search::{
        Anneal, Exhaustive, Genetic, HillClimb, RandomSearch, SearchStrategy,
    };
    pub use crate::coordinator::spec::{Config, TuningSpec};
    pub use crate::coordinator::tuner::{TuneOutcome, TuneStats, Tuner, VariantResult};
    pub use crate::runtime::{Executable, Registry, Runtime, TensorData};
    pub use crate::service::{Client, Request, ServeOpts, Server, TaskKind, TuningTask};
    pub use crate::worker::{Worker, WorkerOpts};
}
