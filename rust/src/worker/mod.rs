//! The distributed tuning worker — `portatune work`.
//!
//! A worker is the execution half of the serve daemon's [`TaskQueue`]:
//! it loops **lease → execute → report** against a remote daemon over
//! the ordinary wire [`Client`], so any machine that can reach the
//! daemon can help drain its staleness backlog.  The daemon never
//! blocks on a worker: a lease that stops heartbeating expires and the
//! task requeues, so killing a worker mid-task loses nothing.
//!
//! What each task kind executes:
//!
//! * **retune** — the batched [`Tuner`] over the worker's artifact
//!   registry (one (kernel, workload) pair), reported back through the
//!   `record` op so the daemon's decision cache is invalidated;
//! * **sweep** — [`sweep_native`] host-side (no artifacts needed),
//!   every per-shape winner reported through `record`;
//! * **portfolio-rebuild** — [`sweep_native`] plus
//!   [`CostMatrix::build_portfolio`], the sweep entries reported
//!   through `record` and the rebuilt portfolio through
//!   `record-portfolio`, so the daemon serves the fresh `built_at`
//!   immediately.
//!
//! By default a worker leases only tasks for **its own platform key**
//! — measurements taken on this machine describe this machine, and
//! recording them under a foreign key would poison that platform's
//! shard.  `--any-platform` opts into taking foreign tasks anyway
//! (results still record under the worker's true key: that is where
//! the fresh data lands after a hardware change).
//!
//! While a task executes, a background thread heartbeats the lease so
//! a long sweep cannot expire out from under a *live* worker; the
//! heartbeat stops the moment execution ends (success or failure).
//!
//! [`TaskQueue`]: crate::service::scheduler::TaskQueue
//! [`Tuner`]: crate::coordinator::tuner::Tuner
//! [`sweep_native`]: crate::coordinator::portfolio::sweep_native
//! [`CostMatrix::build_portfolio`]: crate::coordinator::portfolio::CostMatrix::build_portfolio

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::measure::MeasureConfig;
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::portfolio::{sweep_native, GemmSweep};
use crate::coordinator::search::Exhaustive;
use crate::coordinator::tuner::Tuner;
use crate::obs::{self, trace};
use crate::runtime::{Registry, Runtime};
use crate::service::audit::{AuditEvent, AuditLog};
use crate::service::client::{Client, LeasedTask};
use crate::service::faults::{self, InjectionPoint};
use crate::service::protocol::Request;
use crate::service::scheduler::{TaskKind, TuningTask, DEFAULT_LEASE_TTL_S};

/// Worker configuration (the `portatune work` flags).
#[derive(Debug, Clone)]
pub struct WorkerOpts {
    /// Artifact root for retune tasks (sweeps need none).
    pub artifacts: PathBuf,
    /// Lease TTL requested from the daemon.
    pub lease_ttl_s: u64,
    /// Heartbeat interval while executing; 0 derives `lease_ttl_s / 3`
    /// (at least one second).
    pub heartbeat_s: u64,
    /// Smoke-sized sweeps and measurement profiles.
    pub quick: bool,
    /// Deterministic input seed for sweeps.
    pub seed: u64,
    /// Tuner batch size for retune tasks.
    pub batch: usize,
    /// Lease tasks for any platform, not just this machine's key.
    pub any_platform: bool,
    /// Portfolio size cap for rebuild tasks.
    pub k_max: usize,
    /// Retention target for rebuild tasks.
    pub target: f64,
    /// Local audit log path (`--audit`); `None` leaves no worker-side
    /// trail.  The worker's log is its own chain — it records what
    /// *this* machine leased and settled, complementing the daemon's.
    pub audit: Option<PathBuf>,
}

impl Default for WorkerOpts {
    fn default() -> WorkerOpts {
        WorkerOpts {
            artifacts: PathBuf::from("artifacts"),
            lease_ttl_s: DEFAULT_LEASE_TTL_S,
            heartbeat_s: 0,
            quick: false,
            seed: 42,
            batch: 4,
            any_platform: false,
            k_max: 4,
            target: 0.9,
            audit: None,
        }
    }
}

/// What one executed task looked like.
#[derive(Debug, Clone)]
pub struct TaskReport {
    /// The lease that owned the task.
    pub lease_id: u64,
    /// The task itself.
    pub task: TuningTask,
    /// Whether execution succeeded (and the completion was reported).
    pub ok: bool,
    /// Human-oriented outcome description (the error text on failure).
    pub detail: String,
}

/// Tally of a worker run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkSummary {
    /// Tasks executed and completed.
    pub completed: u64,
    /// Tasks that failed (reported via `task-fail`).
    pub failed: u64,
}

/// A fleet worker bound to one daemon.
pub struct Worker {
    client: Client,
    host: Fingerprint,
    host_key: String,
    opts: WorkerOpts,
    audit: Option<AuditLog>,
}

impl Worker {
    /// A worker speaking to `client`, identifying as this machine.
    /// An unopenable `--audit` path disables the trail (with a log
    /// line) rather than killing the worker: auditing is evidence,
    /// not a precondition for draining tasks.
    pub fn new(client: Client, opts: WorkerOpts) -> Worker {
        let host = Fingerprint::detect();
        let host_key = host.key();
        let audit = opts.audit.as_ref().and_then(|p| match AuditLog::open(p) {
            Ok(log) => Some(log),
            Err(e) => {
                eprintln!("[work] audit disabled ({e:#})");
                None
            }
        });
        Worker { client, host, host_key, opts, audit }
    }

    /// Append to the worker's local audit log, when one is open.
    fn audit(&self, event: AuditEvent) {
        if let Some(log) = &self.audit {
            if let Err(e) = log.append(event) {
                eprintln!("[work] audit append failed: {e:#}");
            }
        }
    }

    /// The platform key this worker records results under.
    pub fn host_key(&self) -> &str {
        &self.host_key
    }

    /// Heartbeat cadence for a lease the daemon granted at
    /// `granted_ttl_s`.  Derived from the *granted* TTL, not the
    /// requested one — the daemon caps absurd requests, and beating at
    /// a third of a TTL the lease does not actually have would let it
    /// expire (and requeue for a second worker) under a live one.
    fn heartbeat_interval(&self, granted_ttl_s: u64) -> Duration {
        let secs = if self.opts.heartbeat_s > 0 {
            self.opts.heartbeat_s
        } else {
            (granted_ttl_s / 3).max(1)
        };
        Duration::from_secs(secs)
    }

    /// Lease one task, execute it, and report the result.  `Ok(None)`
    /// when the daemon had no matching task.  Execution errors are
    /// *reported* (`task-fail`), not returned: the worker loop should
    /// keep draining; only transport-level failures surface as `Err`.
    ///
    /// When tracing is armed, the whole cycle runs under one ambient
    /// trace id: every wire call the cycle makes (lease, records,
    /// settle) carries it, so the daemon's request spans line up with
    /// this worker's lease/execute/report spans in one timeline.
    pub fn run_once(&self) -> Result<Option<TaskReport>> {
        let ambient = trace::enabled().then(trace::fresh_trace_id);
        trace::set_current(ambient.clone());
        let result = self.lease_execute_report(ambient.as_deref());
        trace::set_current(None);
        result
    }

    fn lease_execute_report(&self, trace_id: Option<&str>) -> Result<Option<TaskReport>> {
        let platform = (!self.opts.any_platform).then(|| self.host_key.clone());
        let lease_span = trace::span("lease", "worker");
        let leased = self.client.lease_task(None, platform, Some(self.opts.lease_ttl_s));
        if let Some(s) = lease_span {
            s.finish(trace_id);
        }
        let Some(leased) = leased? else {
            return Ok(None);
        };
        self.audit(AuditEvent::TaskLeased {
            lease_id: leased.lease_id,
            kind: leased.task.kind.as_str().to_string(),
            platform: leased.task.platform_key.clone(),
            kernel: leased.task.kernel.clone(),
        });
        let granted_ttl_s = if leased.ttl_s > 0 { leased.ttl_s } else { self.opts.lease_ttl_s };
        let heartbeat = HeartbeatGuard::spawn(
            self.client.clone(),
            leased.lease_id,
            self.heartbeat_interval(granted_ttl_s),
        );
        // Execution runs under `catch_unwind`: a panicking kernel or
        // sweep must not unwind past the report step — the daemon
        // should learn "this task failed" *now* via `task-fail`, not
        // a lease TTL later.  The heartbeat guard stops either way.
        let exec_span = trace::span(format!("execute:{}", leased.task.kind.as_str()), "worker");
        let exec_started = Instant::now();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.execute(&leased)
        }))
        .unwrap_or_else(|panic| {
            Err(anyhow::anyhow!("task execution panicked: {}", panic_message(panic.as_ref())))
        });
        obs::metrics().worker_execute_us.record(exec_started.elapsed().as_micros() as u64);
        if let Some(s) = exec_span {
            s.finish(trace_id);
        }
        drop(heartbeat);
        if faults::hit(InjectionPoint::WorkerCrash) {
            // Fault injection: die between executing and settling,
            // like a worker killed mid-report.  Deliberately no
            // `task-fail` either — only lease expiry may recover the
            // task, which is exactly what the chaos suite asserts.
            anyhow::bail!(
                "fault-injected worker crash before settling lease {}",
                leased.lease_id
            );
        }
        let report_span = trace::span("report", "worker");
        let report_started = Instant::now();
        let settled = match outcome {
            Ok(detail) => {
                let completed = self
                    .client
                    .complete_task(leased.lease_id)
                    .context("reporting task completion");
                match completed {
                    Ok(_) => {
                        self.audit(AuditEvent::TaskCompleted { lease_id: leased.lease_id });
                        Ok(Some(TaskReport {
                            lease_id: leased.lease_id,
                            task: leased.task,
                            ok: true,
                            detail,
                        }))
                    }
                    Err(e) => Err(e),
                }
            }
            Err(e) => {
                let detail = format!("{e:#}");
                // Best-effort: if even the failure report cannot reach
                // the daemon, the lease TTL requeues the task anyway.
                let _ = self.client.fail_task(leased.lease_id, &detail);
                self.audit(AuditEvent::TaskFailed {
                    lease_id: leased.lease_id,
                    error: detail.clone(),
                });
                Ok(Some(TaskReport {
                    lease_id: leased.lease_id,
                    task: leased.task,
                    ok: false,
                    detail,
                }))
            }
        };
        obs::metrics().worker_report_us.record(report_started.elapsed().as_micros() as u64);
        if let Some(s) = report_span {
            s.finish(trace_id);
        }
        settled
    }

    /// Drain loop.  With `once`, waits up to `wait` for a task to
    /// appear, executes exactly one, and errors if it failed (or none
    /// arrived) — the CI smoke shape; five consecutive transport
    /// errors are fatal there.  Otherwise polls forever every `poll`
    /// and **survives daemon outages indefinitely**: transport errors
    /// back off (capped at ten polls) and the worker re-leases once
    /// the daemon is back, so a daemon restart never kills the fleet.
    pub fn run(&self, once: bool, poll: Duration, wait: Duration) -> Result<WorkSummary> {
        let mut summary = WorkSummary::default();
        let started = Instant::now();
        let mut consecutive_errors: u32 = 0;
        loop {
            match self.run_once() {
                Ok(Some(report)) => {
                    consecutive_errors = 0;
                    let task = &report.task;
                    let label = match &task.tag {
                        Some(tag) => format!("{}/{}", task.kernel, tag),
                        None => task.kernel.clone(),
                    };
                    if report.ok {
                        summary.completed += 1;
                        eprintln!(
                            "[work] completed {} {} for {}: {}",
                            task.kind.as_str(),
                            label,
                            task.platform_key,
                            report.detail
                        );
                    } else {
                        summary.failed += 1;
                        eprintln!(
                            "[work] FAILED {} {} for {}: {}",
                            task.kind.as_str(),
                            label,
                            task.platform_key,
                            report.detail
                        );
                    }
                    if once {
                        anyhow::ensure!(
                            report.ok,
                            "task failed: {} (see daemon log)",
                            report.detail
                        );
                        return Ok(summary);
                    }
                }
                Ok(None) => {
                    // A successful empty poll proves the daemon is
                    // reachable: non-consecutive blips must not
                    // accumulate into a fatal "unreachable" verdict on
                    // a long-running idle worker.
                    consecutive_errors = 0;
                    if once && started.elapsed() >= wait {
                        anyhow::bail!(
                            "no task available within {:.0}s (is the daemon's staleness \
                             scan running, and does this worker's platform filter match?)",
                            wait.as_secs_f64()
                        );
                    }
                    std::thread::sleep(poll);
                }
                Err(e) => {
                    consecutive_errors += 1;
                    if once && consecutive_errors >= 5 {
                        return Err(e.context("daemon unreachable after 5 attempts"));
                    }
                    let backoff = poll * consecutive_errors.min(10);
                    eprintln!(
                        "[work] daemon error (attempt {consecutive_errors}, retrying in \
                         {backoff:?}): {e:#}"
                    );
                    std::thread::sleep(backoff);
                }
            }
        }
    }

    /// Execute one leased task; returns the completion detail line.
    fn execute(&self, leased: &LeasedTask) -> Result<String> {
        let task = &leased.task;
        match task.kind {
            TaskKind::Sweep => self.execute_sweep(task),
            TaskKind::PortfolioRebuild => self.execute_rebuild(task),
            TaskKind::Retune => self.execute_retune(task),
        }
    }

    /// Run the native sweep and report every per-shape winner through
    /// `record`.  Returns the sweep and how many shapes were recorded
    /// (shared by the sweep and portfolio-rebuild task kinds).
    fn sweep_and_record(&self, task: &TuningTask) -> Result<(GemmSweep, usize)> {
        let sweep_started = Instant::now();
        let sweep = sweep_native(&task.kernel, self.opts.quick, self.opts.seed, &self.host)?;
        let entries = sweep.entries(&self.host_key, "worker-sweep");
        let n = entries.len();
        // Sweep cost is one wall-clock bill split evenly across the
        // recorded shapes, so the ledger's spend matches what this
        // machine actually burned regardless of shape count.
        let spend_each_ms =
            ((sweep_started.elapsed().as_millis() as u64) / (n.max(1) as u64)).max(1);
        for entry in entries {
            self.client
                .record_with_spend(entry, Some(self.host.clone()), Some(spend_each_ms))
                .context("recording sweep entry")?;
        }
        Ok((sweep, n))
    }

    /// Execute a sweep task.
    fn execute_sweep(&self, task: &TuningTask) -> Result<String> {
        let (_, n) = self.sweep_and_record(task)?;
        Ok(format!("swept {n} shape(s) of {}", task.kernel))
    }

    /// Sweep, rebuild the portfolio, and report both.
    fn execute_rebuild(&self, task: &TuningTask) -> Result<String> {
        let (sweep, shapes) = self.sweep_and_record(task)?;
        // Selection cost on top of the (already-billed) sweep: the
        // timer starts after sweep_and_record so the ledger never sees
        // the same wall clock twice.
        let select_started = Instant::now();
        let built = sweep.matrix.build_portfolio(self.opts.k_max, self.opts.target)?;
        let k = built.len();
        let retained = built.retained;
        let spend_ms = (select_started.elapsed().as_millis() as u64).max(1);
        self.client
            .call(&Request::RecordPortfolio {
                platform: Some(self.host_key.clone()),
                portfolio: Box::new(built),
                fingerprint: Some(self.host.clone()),
                spend_ms: Some(spend_ms),
            })
            .context("recording rebuilt portfolio")?;
        Ok(format!(
            "rebuilt {} portfolio: {k} config(s) retain {:.1}% over {shapes} shape(s)",
            task.kernel,
            retained * 100.0
        ))
    }

    /// Re-tune one (kernel, workload) through the artifact registry.
    fn execute_retune(&self, task: &TuningTask) -> Result<String> {
        let tag = task.tag.as_deref().context("retune task carries no workload")?;
        let runtime = Runtime::cpu().context("opening runtime for retune")?;
        let registry = Registry::open(runtime, &self.opts.artifacts)
            .context("opening artifact registry for retune")?;
        let mut tuner = Tuner::new(&registry);
        tuner.batch = self.opts.batch.max(1);
        if self.opts.quick {
            tuner.measure_cfg = MeasureConfig::quick();
        }
        let mut strategy = Exhaustive::new();
        let tune_started = Instant::now();
        let outcome = tuner.tune(&task.kernel, tag, &mut strategy, usize::MAX)?;
        // Spend = the tuner's own compile+measure accounting, wall
        // clock as the stub-runtime fallback (TuneStats reports 0 ms
        // there, but the machine was still busy).
        let worked_ms = outcome.stats.compile_ms + outcome.stats.measure_ms;
        let spend_ms = if worked_ms.is_finite() && worked_ms >= 1.0 {
            worked_ms.round() as u64
        } else {
            (tune_started.elapsed().as_millis() as u64).max(1)
        };
        let entry = tuner.entry_for(&outcome);
        let speedup = entry.speedup();
        let best = entry.best_config_id.clone();
        self.client
            .record_with_spend(entry, Some(outcome.platform.clone()), Some(spend_ms))
            .context("recording retune result")?;
        Ok(format!("retuned {}/{tag}: {best} ({speedup:.2}x)", task.kernel))
    }
}

/// Best-effort text of a caught panic payload (panics carry `&str` or
/// `String` in practice; anything else gets a placeholder).
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Background lease keep-alive for the duration of one execution.
/// Heartbeat failures are ignored: if the daemon is gone the lease
/// will expire and requeue, which is the designed recovery path.
struct HeartbeatGuard {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HeartbeatGuard {
    fn spawn(client: Client, lease_id: u64, interval: Duration) -> HeartbeatGuard {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            let slice = Duration::from_millis(100);
            loop {
                let mut slept = Duration::ZERO;
                while slept < interval {
                    if flag.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(slice);
                    slept += slice;
                }
                if flag.load(Ordering::Relaxed) {
                    return;
                }
                let _ = client.heartbeat_task(lease_id);
            }
        });
        HeartbeatGuard { stop, handle: Some(handle) }
    }
}

impl Drop for HeartbeatGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heartbeat_interval_derives_from_granted_ttl() {
        let mut opts = WorkerOpts::default();
        let worker = Worker::new(Client::tcp("127.0.0.1:1"), opts.clone());
        // The *granted* TTL drives the cadence — a server-capped lease
        // must still be heartbeated often enough to stay alive even if
        // the worker asked for far more.
        assert_eq!(worker.heartbeat_interval(90), Duration::from_secs(30));
        assert_eq!(worker.heartbeat_interval(86_400), Duration::from_secs(28_800));
        // A degenerate TTL still heartbeats at least every second.
        assert_eq!(worker.heartbeat_interval(1), Duration::from_secs(1));
        // An explicit --heartbeat overrides the derivation.
        opts.heartbeat_s = 7;
        let worker = Worker::new(Client::tcp("127.0.0.1:1"), opts);
        assert_eq!(worker.heartbeat_interval(90), Duration::from_secs(7));
    }

    #[test]
    fn run_once_with_unreachable_daemon_is_a_transport_error() {
        // Port 1 is never listening; the lease call must surface as a
        // connection error, not a panic or a silent None.
        let worker = Worker::new(Client::tcp("127.0.0.1:1"), WorkerOpts::default());
        assert!(worker.run_once().is_err());
    }

    #[test]
    fn panic_payloads_render_as_text() {
        let caught =
            std::panic::catch_unwind(|| panic!("kernel exploded")).expect_err("must panic");
        assert_eq!(panic_message(caught.as_ref()), "kernel exploded");
        let caught = std::panic::catch_unwind(|| panic!("{} exploded", "sweep"))
            .expect_err("must panic");
        assert_eq!(panic_message(caught.as_ref()), "sweep exploded");
    }
}
