//! Lock-free log-scaled latency histogram.
//!
//! The recording surface the whole telemetry layer stands on: a fixed
//! array of atomic bins, so `record` is one index computation plus one
//! relaxed `fetch_add` — safe to call from every hot path, every
//! thread, with no allocation and no lock.  The bucketing is HDR-style
//! log-linear: values 0–3 get exact bins, and every power-of-two
//! octave above that is split into four equal sub-buckets, so any
//! reported bound overstates the true value by less than 25%.  That
//! bound is what `quantile` returns — the *upper* edge of the bucket
//! containing the requested rank — which keeps quantiles monotone in
//! `q` and never under-reports a latency.
//!
//! Units are the caller's business: the serve path records
//! microseconds, the task queue records seconds of queue age, the
//! fleet simulation records virtual seconds of staleness.  One
//! `u64`-valued histogram covers nanoseconds to centuries either way.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::{self, Json};

/// Sub-buckets per power-of-two octave (4 ⇒ ≤ 25% bucket error).
const SUB: usize = 4;

/// Total bins: 4 exact bins for 0–3, then 4 sub-buckets for each of
/// the 62 octaves `[2^2, 2^63)` — covering the entire `u64` range.
pub const N_BINS: usize = SUB + 62 * SUB;

/// A mergeable, thread-safe latency histogram with fixed log-scaled
/// buckets (see the module docs for the scheme).
#[derive(Debug)]
pub struct Histogram {
    bins: [AtomicU64; N_BINS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            bins: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// The bin index `value` lands in.
    pub fn bucket_index(value: u64) -> usize {
        if value < SUB as u64 {
            return value as usize;
        }
        // Highest set bit m >= 2; the two bits below it pick the
        // sub-bucket within the octave [2^m, 2^(m+1)).
        let m = 63 - value.leading_zeros() as usize;
        let sub = ((value >> (m - 2)) & 0b11) as usize;
        SUB + (m - 2) * SUB + sub
    }

    /// Inclusive `(lo, hi)` value bounds of bin `idx`.
    pub fn bucket_bounds(idx: usize) -> (u64, u64) {
        assert!(idx < N_BINS, "bin index {idx} out of range");
        if idx < SUB {
            return (idx as u64, idx as u64);
        }
        let m = 2 + (idx - SUB) / SUB;
        let sub = ((idx - SUB) % SUB) as u64;
        let width = 1u64 << (m - 2);
        let lo = (1u64 << m) + sub * width;
        (lo, lo + (width - 1))
    }

    /// Record one observation.
    pub fn record(&self, value: u64) {
        self.bins[Self::bucket_index(value)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values (caller's unit).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Whether nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Mean recorded value (0.0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// A point-in-time copy of every bin count.
    pub fn snapshot(&self) -> [u64; N_BINS] {
        std::array::from_fn(|i| self.bins[i].load(Ordering::Relaxed))
    }

    /// Upper bound of the bucket holding the `q`-quantile observation
    /// (nearest-rank over the bucketed counts; 0 when empty).  The
    /// bound overstates the true value by < 25% — see module docs —
    /// and is monotone non-decreasing in `q`.
    pub fn quantile(&self, q: f64) -> u64 {
        let bins = self.snapshot();
        let total: u64 = bins.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (idx, &n) in bins.iter().enumerate() {
            cum += n;
            if cum >= rank {
                return Self::bucket_bounds(idx).1;
            }
        }
        // Unreachable (cum == total >= rank by the last bin), but a
        // defensive max-bound beats a panic in a telemetry path.
        u64::MAX
    }

    /// Fold `other`'s observations into `self` (bin-wise addition:
    /// associative, commutative, and lossless on counts).
    pub fn merge(&self, other: &Histogram) {
        for (i, bin) in other.snapshot().iter().enumerate() {
            if *bin > 0 {
                self.bins[i].fetch_add(*bin, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }

    /// Summary object for the `metrics` wire op: count, sum, mean, and
    /// the p50/p95/p99 bucket bounds (caller's unit throughout).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("count", json::int(self.count() as i64)),
            ("sum", json::int(self.sum() as i64)),
            ("mean", json::num(self.mean())),
            ("p50", json::int(self.quantile(0.50) as i64)),
            ("p95", json::int(self.quantile(0.95) as i64)),
            ("p99", json::int(self.quantile(0.99) as i64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_get_exact_bins() {
        for v in 0..4u64 {
            let idx = Histogram::bucket_index(v);
            assert_eq!(Histogram::bucket_bounds(idx), (v, v));
        }
    }

    #[test]
    fn bounds_partition_the_range() {
        // Consecutive bins tile u64 with no gaps or overlaps.
        let mut expected_lo = 0u64;
        for idx in 0..N_BINS {
            let (lo, hi) = Histogram::bucket_bounds(idx);
            assert_eq!(lo, expected_lo, "gap before bin {idx}");
            assert!(hi >= lo);
            expected_lo = hi.wrapping_add(1);
        }
        assert_eq!(expected_lo, 0, "last bin must end at u64::MAX");
    }

    #[test]
    fn quantile_bound_is_within_25_percent() {
        for v in [5u64, 100, 999, 123_456, 10_000_000_000] {
            let h = Histogram::new();
            h.record(v);
            let q = h.quantile(0.99);
            assert!(q >= v, "quantile must not under-report: {q} < {v}");
            assert!((q as f64) < v as f64 * 1.25, "bucket error too wide: {q} for {v}");
        }
    }

    #[test]
    fn quantiles_walk_the_distribution() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(10);
        }
        for _ in 0..10 {
            h.record(1000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 >= 10 && p50 < 13, "p50 was {p50}");
        assert!(p99 >= 1000 && p99 < 1250, "p99 was {p99}");
        assert!((h.mean() - 109.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_counts() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(7);
        b.record(7);
        b.record(70);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.sum(), 84);
        assert_eq!(a.snapshot()[Histogram::bucket_index(7)], 2);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(0));
    }
}
