//! End-to-end telemetry: the metrics registry, latency histograms,
//! trace spans, and exposition formats.
//!
//! Three std-only pieces (see `docs/OBSERVABILITY.md` for the operator
//! view):
//!
//! * [`hist::Histogram`] — the lock-free log-scaled latency histogram
//!   everything records into;
//! * [`trace`] — Chrome-trace/Perfetto span emission behind one
//!   relaxed atomic load (the `faults.rs` discipline), plus the
//!   wire-propagated `trace_id`;
//! * this module — the process-wide [`Metrics`] registry, the
//!   Prometheus text rendering behind `--metrics-addr`, and the
//!   slow-op threshold behind `--slow-ms`.
//!
//! Monotonic *counters* deliberately stay where they were: the
//! daemon's [`ServeStats`](crate::service::ServeStats) snapshot is
//! already atomic, already on the wire (`stats`), and already
//! documented — the registry adds the latency *distributions* those
//! counters cannot express, and the exposition surfaces (`metrics`
//! wire op, Prometheus page) merge both.
//!
//! Everything here is global by design, like `faults.rs`: telemetry
//! is recorded from free functions, background threads, and both
//! halves of the wire protocol, and threading a registry handle
//! through all of them would couple every layer to this one.

pub mod hist;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

pub use hist::Histogram;

use crate::util::json::{self, Json};

/// Histogram labels for the per-op latency family: every wire op, the
/// `error` label for unparseable lines, and an `other` fallback so an
/// unknown label can never panic a telemetry path.
pub const OP_LABELS: &[&str] = &[
    "deploy",
    "error",
    "lookup",
    "metrics",
    "ping",
    "portfolio",
    "record",
    "record-portfolio",
    "report",
    "retune-next",
    "shutdown",
    "stats",
    "task-complete",
    "task-fail",
    "task-heartbeat",
    "task-lease",
    "other",
];

/// The process-wide latency-histogram registry.
#[derive(Debug)]
pub struct Metrics {
    /// Per-op request latency (µs), one histogram per [`OP_LABELS`]
    /// entry.
    op_latency: Vec<Histogram>,
    /// Shard-file read+parse time (µs) on decision-cache misses.
    pub shard_read_us: Histogram,
    /// Shard lock-file acquisition wait (µs) on the write path.
    pub lock_wait_us: Histogram,
    /// Decision/portfolio-cache hit latency (µs).
    pub lru_hit_us: Histogram,
    /// Transfer-ranking cost (µs): all-shard read + similarity scoring
    /// on deploy/portfolio misses.
    pub transfer_rank_us: Histogram,
    /// Task age between enqueue and lease (seconds).
    pub queue_age_at_lease_s: Histogram,
    /// Worker task execution time (µs).
    pub worker_execute_us: Histogram,
    /// Worker result-reporting time (µs): the settle round-trip.
    pub worker_report_us: Histogram,
}

impl Metrics {
    fn new() -> Metrics {
        Metrics {
            op_latency: OP_LABELS.iter().map(|_| Histogram::new()).collect(),
            shard_read_us: Histogram::new(),
            lock_wait_us: Histogram::new(),
            lru_hit_us: Histogram::new(),
            transfer_rank_us: Histogram::new(),
            queue_age_at_lease_s: Histogram::new(),
            worker_execute_us: Histogram::new(),
            worker_report_us: Histogram::new(),
        }
    }

    /// The latency histogram for one op label (unknown labels fall
    /// back to `other`).
    pub fn op(&self, name: &str) -> &Histogram {
        let idx = OP_LABELS.iter().position(|&l| l == name).unwrap_or(OP_LABELS.len() - 1);
        &self.op_latency[idx]
    }

    /// Every histogram with its exposition name, seconds divisor, and
    /// optional `op` label — the single source both exposition formats
    /// render from.
    fn catalog(&self) -> Vec<(&'static str, f64, Option<&'static str>, &Histogram)> {
        let mut entries: Vec<(&'static str, f64, Option<&'static str>, &Histogram)> = OP_LABELS
            .iter()
            .zip(&self.op_latency)
            .map(|(&label, h)| ("op_latency_seconds", 1e6, Some(label), h))
            .collect();
        entries.extend([
            ("shard_read_seconds", 1e6, None, &self.shard_read_us),
            ("lock_wait_seconds", 1e6, None, &self.lock_wait_us),
            ("lru_hit_seconds", 1e6, None, &self.lru_hit_us),
            ("transfer_rank_seconds", 1e6, None, &self.transfer_rank_us),
            ("queue_age_at_lease_seconds", 1.0, None, &self.queue_age_at_lease_s),
            ("worker_execute_seconds", 1e6, None, &self.worker_execute_us),
            ("worker_report_seconds", 1e6, None, &self.worker_report_us),
        ]);
        entries
    }

    /// The full registry as JSON (the `metrics` wire op's payload):
    /// per-op latency summaries nested under `op_latency_us`, each
    /// named histogram beside it, all in the units they record.
    pub fn to_json(&self) -> Json {
        let ops = OP_LABELS
            .iter()
            .zip(&self.op_latency)
            .map(|(&label, h)| (label.to_string(), h.to_json()))
            .collect();
        json::obj(vec![
            ("op_latency_us", Json::Obj(ops)),
            ("shard_read_us", self.shard_read_us.to_json()),
            ("lock_wait_us", self.lock_wait_us.to_json()),
            ("lru_hit_us", self.lru_hit_us.to_json()),
            ("transfer_rank_us", self.transfer_rank_us.to_json()),
            ("queue_age_at_lease_s", self.queue_age_at_lease_s.to_json()),
            ("worker_execute_us", self.worker_execute_us.to_json()),
            ("worker_report_us", self.worker_report_us.to_json()),
        ])
    }

    /// Prometheus text-format rendering of every histogram in the
    /// registry (`_bucket`/`_sum`/`_count` series, `le` in seconds).
    /// Only buckets that hold observations are emitted (plus `+Inf`)
    /// — 252 fixed bins per histogram would swamp the page.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        let mut last_family = "";
        for (family, divisor, label, h) in self.catalog() {
            if family != last_family {
                out.push_str(&format!("# TYPE portatune_{family} histogram\n"));
                last_family = family;
            }
            let labels = |le: Option<String>| -> String {
                let mut parts = Vec::new();
                if let Some(op) = label {
                    parts.push(format!("op=\"{op}\""));
                }
                if let Some(le) = le {
                    parts.push(format!("le=\"{le}\""));
                }
                if parts.is_empty() {
                    String::new()
                } else {
                    format!("{{{}}}", parts.join(","))
                }
            };
            let bins = h.snapshot();
            let mut cum = 0u64;
            for (idx, &n) in bins.iter().enumerate() {
                if n == 0 {
                    continue;
                }
                cum += n;
                let le = Histogram::bucket_bounds(idx).1 as f64 / divisor;
                out.push_str(&format!(
                    "portatune_{family}_bucket{} {cum}\n",
                    labels(Some(le.to_string()))
                ));
            }
            out.push_str(&format!(
                "portatune_{family}_bucket{} {cum}\n",
                labels(Some("+Inf".to_string()))
            ));
            out.push_str(&format!(
                "portatune_{family}_sum{} {}\n",
                labels(None),
                h.sum() as f64 / divisor
            ));
            out.push_str(&format!("portatune_{family}_count{} {}\n", labels(None), h.count()));
        }
        out
    }
}

/// The process-wide metrics registry.
pub fn metrics() -> &'static Metrics {
    static METRICS: OnceLock<Metrics> = OnceLock::new();
    METRICS.get_or_init(Metrics::new)
}

/// Slow-op threshold in microseconds; 0 disables the slow-op log.
static SLOW_OP_US: AtomicU64 = AtomicU64::new(0);

/// Arm the slow-op log: requests slower than `ms` milliseconds get a
/// structured stderr line (0 disarms).
pub fn set_slow_op_ms(ms: u64) {
    SLOW_OP_US.store(ms.saturating_mul(1000), Ordering::SeqCst);
}

/// The armed slow-op threshold in microseconds (0 = off).
pub fn slow_op_us() -> u64 {
    SLOW_OP_US.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_labels_resolve_and_unknown_falls_back() {
        let m = Metrics::new();
        m.op("lookup").record(10);
        assert_eq!(m.op("lookup").count(), 1);
        m.op("no-such-op").record(10);
        assert_eq!(m.op("other").count(), 1);
    }

    #[test]
    fn registry_json_names_every_histogram() {
        let m = Metrics::new();
        m.op("ping").record(100);
        m.queue_age_at_lease_s.record(30);
        let j = m.to_json();
        for key in [
            "op_latency_us",
            "shard_read_us",
            "lock_wait_us",
            "lru_hit_us",
            "transfer_rank_us",
            "queue_age_at_lease_s",
            "worker_execute_us",
            "worker_report_us",
        ] {
            assert!(j.get(key).is_some(), "missing registry key {key}");
        }
        assert_eq!(
            j.get("op_latency_us")
                .and_then(|o| o.get("ping"))
                .and_then(|h| h.get("count"))
                .and_then(Json::as_u64),
            Some(1)
        );
    }

    #[test]
    fn prometheus_text_renders_buckets_in_seconds() {
        let m = Metrics::new();
        m.op("lookup").record(1000); // 1ms
        m.queue_age_at_lease_s.record(60);
        let text = m.prometheus_text();
        assert!(text.contains("# TYPE portatune_op_latency_seconds histogram"));
        assert!(text.contains("portatune_op_latency_seconds_count{op=\"lookup\"} 1"));
        assert!(text.contains("le=\"+Inf\""), "+Inf bucket required: {text}");
        // 1000µs lands in a bucket whose upper bound is ~0.001s.
        let bucket_line = text
            .lines()
            .find(|l| l.starts_with("portatune_op_latency_seconds_bucket{op=\"lookup\",le=\"0."))
            .expect("a finite lookup bucket");
        assert!(bucket_line.ends_with(" 1"));
        assert!(text.contains("portatune_queue_age_at_lease_seconds_count 1"));
    }

    #[test]
    fn slow_op_threshold_arms_in_microseconds() {
        set_slow_op_ms(0);
        assert_eq!(slow_op_us(), 0);
        set_slow_op_ms(250);
        assert_eq!(slow_op_us(), 250_000);
        set_slow_op_ms(0);
    }
}
