//! Chrome-trace-event span emission, wired like `faults.rs`: a single
//! relaxed atomic load when tracing is off, a global sink installed
//! once when it is on.
//!
//! `install(path)` opens the sink and writes the opening `[` of the
//! Chrome **JSON Array Format**; every finished span then appends one
//! complete event (`"ph":"X"`) object followed by a comma and newline.
//! Both Perfetto and `chrome://tracing` accept an array whose closing
//! `]` never arrives, so a killed process still leaves a loadable
//! trace.  Timestamps are wall-clock epoch microseconds — not a
//! process-relative monotonic clock — so spans emitted by the daemon
//! and a worker on the same machine line up on one timeline, and a
//! shared `trace_id` arg links one request's spans across the two
//! processes.
//!
//! The per-thread *current trace id* lets a caller scope every span
//! and outgoing wire request to one logical operation: the worker sets
//! it around each task, the client attaches it to request lines, the
//! daemon echoes it in replies and audit events.

use std::cell::{Cell, RefCell};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// The installed sink (a line-buffered trace file), if any.
fn sink() -> &'static Mutex<Option<std::io::BufWriter<std::fs::File>>> {
    static SINK: OnceLock<Mutex<Option<std::io::BufWriter<std::fs::File>>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

/// Whether span emission is on.  One relaxed load — the only cost
/// every instrumented path pays when tracing is disabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Open `path` as the process-wide trace sink and enable emission.
/// The file is truncated and seeded with the array opener.
pub fn install(path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {}", path.display()))?;
    let mut writer = std::io::BufWriter::new(file);
    writer.write_all(b"[\n").context("writing trace header")?;
    *lock_sink() = Some(writer);
    ENABLED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Disable emission and drop the sink (flushing it).  Primarily for
/// tests; a daemon normally traces until exit.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    if let Some(mut writer) = lock_sink().take() {
        let _ = writer.flush();
    }
}

fn lock_sink() -> std::sync::MutexGuard<'static, Option<std::io::BufWriter<std::fs::File>>> {
    sink().lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Wall-clock epoch microseconds (the Chrome trace `ts` clock).
fn epoch_micros() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Small stable per-thread id for the trace `tid` field.
fn thread_tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: Cell<u64> = const { Cell::new(0) };
    }
    TID.with(|tid| {
        if tid.get() == 0 {
            tid.set(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        tid.get()
    })
}

thread_local! {
    static CURRENT: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// Set (or clear) this thread's current trace id.
pub fn set_current(id: Option<String>) {
    CURRENT.with(|c| *c.borrow_mut() = id);
}

/// This thread's current trace id, if one is set.
pub fn current() -> Option<String> {
    CURRENT.with(|c| c.borrow().clone())
}

/// A process-unique trace id: pid + wall-clock nanos + a process-wide
/// sequence (the same uniqueness recipe as the client's request ids —
/// equality is the only operation anyone performs on it).
pub fn fresh_trace_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!("t{:x}-{nanos:x}-{seq:x}", std::process::id())
}

/// An open span: started now, emitted on [`Span::finish`].
#[derive(Debug)]
pub struct Span {
    name: String,
    cat: &'static str,
    ts_us: u64,
    started: Instant,
}

/// Start a span when tracing is enabled (`None` otherwise, for free).
pub fn span(name: impl Into<String>, cat: &'static str) -> Option<Span> {
    if !enabled() {
        return None;
    }
    Some(Span { name: name.into(), cat, ts_us: epoch_micros(), started: Instant::now() })
}

impl Span {
    /// Rename an open span (the server learns the op only after
    /// decoding the request the span already covers).
    pub fn set_name(&mut self, name: impl Into<String>) {
        self.name = name.into();
    }

    /// Close the span and emit its complete event, tagged with the
    /// trace id when one is known.
    pub fn finish(self, trace_id: Option<&str>) {
        let dur_us = self.started.elapsed().as_micros() as u64;
        emit_event(&self.name, self.cat, self.ts_us, dur_us, trace_id);
    }
}

/// Append one Chrome complete event (`ph:"X"`) to the sink.
fn emit_event(name: &str, cat: &'static str, ts_us: u64, dur_us: u64, trace_id: Option<&str>) {
    let mut args: Vec<(&str, Json)> = Vec::new();
    if let Some(id) = trace_id {
        args.push(("trace_id", json::s(id)));
    }
    let event = json::obj(vec![
        ("ph", json::s("X")),
        ("name", json::s(name)),
        ("cat", json::s(cat)),
        ("ts", json::int(ts_us as i64)),
        ("dur", json::int(dur_us as i64)),
        ("pid", json::int(std::process::id() as i64)),
        ("tid", json::int(thread_tid() as i64)),
        ("args", json::obj(args)),
    ]);
    let mut guard = lock_sink();
    if let Some(writer) = guard.as_mut() {
        // Flush per event: a trace that stops at a crash is most of
        // the point, and tracing is opt-in — throughput is not the
        // budget here.
        let _ = writer
            .write_all(event.compact().as_bytes())
            .and_then(|_| writer.write_all(b",\n"))
            .and_then(|_| writer.flush());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracing_creates_no_spans() {
        // Default state: no sink, no spans, enabled() is one load.
        if !enabled() {
            assert!(span("noop", "test").is_none());
        }
    }

    #[test]
    fn trace_ids_are_unique_and_current_is_thread_local() {
        let ids: std::collections::HashSet<String> =
            (0..64).map(|_| fresh_trace_id()).collect();
        assert_eq!(ids.len(), 64);
        set_current(Some("tid-main".into()));
        assert_eq!(current().as_deref(), Some("tid-main"));
        let other = std::thread::spawn(current).join().unwrap();
        assert!(other.is_none(), "current trace id must not leak across threads");
        set_current(None);
        assert!(current().is_none());
    }

    #[test]
    fn installed_sink_emits_parseable_events() {
        let path = std::env::temp_dir()
            .join(format!("portatune-trace-test-{}.json", std::process::id()));
        install(&path).unwrap();
        let mut s = span("unit", "test").expect("tracing was just enabled");
        s.set_name("unit-renamed");
        s.finish(Some("tid-1"));
        clear();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(text.starts_with("[\n"));
        // Concurrent tests may have emitted their own events while the
        // sink was open; every event line must parse, and ours must be
        // among them.
        let mut saw_ours = false;
        for line in text.lines().skip(1) {
            let event = json::parse(line.trim_end_matches(',')).expect("event must be JSON");
            assert_eq!(event.get("ph").and_then(Json::as_str), Some("X"));
            if event.get("name").and_then(Json::as_str) == Some("unit-renamed") {
                assert_eq!(
                    event.get("args").and_then(|a| a.get("trace_id")).and_then(Json::as_str),
                    Some("tid-1")
                );
                saw_ours = true;
            }
        }
        assert!(saw_ours, "the finished span must be in the file");
    }
}
