//! Service-layer integration: the shard store under concurrent
//! writers, the daemon end-to-end over real TCP, legacy-file merge
//! semantics, v1 → v2 migration, the leased task queue under
//! concurrent workers, and the full daemon ⇄ `portatune work`
//! convergence loop for a stale portfolio.
//!
//! Everything here is hermetic — no XLA runtime, no artifacts — which
//! is the point: the serving layer must work on machines that only
//! *consume* tuned configurations (and the worker's sweep tasks run
//! the native GEMM family host-side).

use std::io::{BufRead, BufReader, Write};
use std::sync::{Arc, Mutex};

use portatune::coordinator::perfdb::{unix_now, DbEntry, PerfDb, ShardedDb};
use portatune::coordinator::platform::Fingerprint;
use portatune::coordinator::portfolio::{Portfolio, PortfolioItem, FEATURE_NAMES};
use portatune::service::{Client, Request, RetryPolicy, ServeOpts, Server, TaskKind};
use portatune::util::json::Json;
use portatune::worker::{Worker, WorkerOpts};

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("portatune-svcit-{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn fp(l2: u64, simd: &[&str]) -> Fingerprint {
    Fingerprint {
        cpu_model: "IT CPU".into(),
        num_cpus: 8,
        simd: simd.iter().map(|s| s.to_string()).collect(),
        cache_l1d_kb: 32,
        cache_l2_kb: l2,
        cache_l3_kb: 8192,
        os: "linux".into(),
    }
}

fn entry(platform: &str, kernel: &str, tag: &str, id: &str, recorded_at: u64) -> DbEntry {
    DbEntry {
        platform_key: platform.into(),
        kernel: kernel.into(),
        tag: tag.into(),
        best_params: [("block_size".to_string(), 512i64)].into_iter().collect(),
        best_config_id: id.into(),
        best_time_s: 1e-3,
        baseline_time_s: 2e-3,
        reference_time_s: 9e-4,
        evaluations: 8,
        strategy: "exhaustive".into(),
        recorded_at,
    }
}

/// N threads × M records into one shard: nothing may be lost.
#[test]
fn concurrent_shard_writers_lose_no_entries() {
    let dir = tmp_dir("writers");
    let db = ShardedDb::open(&dir).unwrap();
    const THREADS: usize = 8;
    const PER_THREAD: usize = 20;
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let db = db.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..PER_THREAD {
                // Unique identity per record: distinct config id.
                let e = entry(
                    "shared-platform",
                    "axpy",
                    "n4096",
                    &format!("cfg_t{t}_i{i}"),
                    1_700_000_000 + (t * PER_THREAD + i) as u64,
                );
                db.record(None, e).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let shard = db.load("shared-platform").unwrap().unwrap();
    assert_eq!(
        shard.entries.len(),
        THREADS * PER_THREAD,
        "lock-file + merge-on-save must keep every concurrent record"
    );
    // The newest record is the lookup answer.
    let latest = shard.latest("axpy", "n4096").unwrap();
    assert_eq!(latest.recorded_at, 1_700_000_000 + (THREADS * PER_THREAD - 1) as u64);
    std::fs::remove_dir_all(&dir).ok();
}

/// Concurrent writers on *different* platforms never contend.
#[test]
fn concurrent_writers_different_platforms() {
    let dir = tmp_dir("multi");
    let db = ShardedDb::open(&dir).unwrap();
    let mut handles = Vec::new();
    for t in 0..6 {
        let db = db.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..10 {
                let e = entry(
                    &format!("platform-{t}"),
                    "dot",
                    "n65536",
                    &format!("cfg_{i}"),
                    1_700_000_000 + i as u64,
                );
                db.record(None, e).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(db.platforms().unwrap().len(), 6);
    for t in 0..6 {
        let shard = db.load(&format!("platform-{t}")).unwrap().unwrap();
        assert_eq!(shard.entries.len(), 10);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Full daemon loop over real TCP: record → lookup → deploy-transfer →
/// stats → shutdown, with a concurrent client burst in the middle.
#[test]
fn daemon_record_lookup_deploy_over_tcp() {
    let dir = tmp_dir("tcp");
    let db = ShardedDb::open(&dir).unwrap();
    let server = Arc::new(Server::new(db, fp(1024, &["avx2", "fma"]), ServeOpts::default()));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = Arc::clone(&server);
    let serve_thread = std::thread::spawn(move || srv.run_tcp(listener).unwrap());
    let client = Client::tcp(addr.clone());

    // Record an entry for a "remote" platform, fingerprint attached.
    let reply = client
        .call(&Request::Record {
            request_id: None,
            entry: Box::new(entry("remote-box", "axpy", "n4096", "b512_u1", unix_now())),
            fingerprint: Some(fp(1024, &["avx2", "fma"])),
            spend_ms: None,
        })
        .unwrap();
    assert_eq!(reply.get("recorded").and_then(Json::as_bool), Some(true));

    // Exact lookup round-trips the entry.
    let reply = client
        .call(&Request::Lookup {
            platform: Some("remote-box".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
        })
        .unwrap();
    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
    assert_eq!(
        reply.get("entry").and_then(|e| e.get("best_config_id")).and_then(Json::as_str),
        Some("b512_u1")
    );

    // Concurrent client burst: every thread must get a coherent reply.
    let mut handles = Vec::new();
    for _ in 0..8 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let client = Client::tcp(addr);
            for _ in 0..10 {
                let reply = client
                    .call(&Request::Lookup {
                        platform: Some("remote-box".into()),
                        kernel: "axpy".into(),
                        workload: "n4096".into(),
                    })
                    .unwrap();
                assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // Deploy for an unseen platform with a near-identical fingerprint:
    // transfer-ranked candidates, nearest first, never an empty miss.
    let reply = client
        .call(&Request::Deploy {
            platform: Some("brand-new-box".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: Some(fp(2048, &["avx2", "fma"])),
        })
        .unwrap();
    assert_eq!(reply.get("source").and_then(Json::as_str), Some("transfer"));
    let cands = reply.get("candidates").and_then(Json::as_arr).unwrap();
    assert!(!cands.is_empty());
    assert_eq!(cands[0].get("config_id").and_then(Json::as_str), Some("b512_u1"));
    assert!(cands[0].get("similarity").and_then(Json::as_f64).unwrap() > 0.5);

    // Counters saw the traffic.
    let reply = client.call(&Request::Stats).unwrap();
    let stats = reply.get("stats").unwrap();
    assert!(stats.get("lookups").and_then(Json::as_u64).unwrap() >= 81);
    assert_eq!(stats.get("records").and_then(Json::as_u64), Some(1));
    assert_eq!(stats.get("transfer_misses").and_then(Json::as_u64), Some(1));
    assert!(stats.get("lru_hits").and_then(Json::as_u64).unwrap() >= 1);

    // Shutdown stops the accept loop; the serve thread exits.
    let reply = client.call(&Request::Shutdown).unwrap();
    assert_eq!(reply.get("stopping").and_then(Json::as_bool), Some(true));
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The daemon over a Unix socket (the CI smoke job uses TCP; this
/// covers the second transport).
#[cfg(unix)]
#[test]
fn daemon_over_unix_socket() {
    let dir = tmp_dir("unix");
    std::fs::create_dir_all(&dir).unwrap();
    let sock = dir.join("portatune.sock");
    let db = ShardedDb::open(dir.join("shards")).unwrap();
    let server = Arc::new(Server::new(db, fp(1024, &["avx2"]), ServeOpts::default()));
    let listener = std::os::unix::net::UnixListener::bind(&sock).unwrap();
    let srv = Arc::clone(&server);
    let serve_thread = std::thread::spawn(move || srv.run_unix(listener).unwrap());
    let client = Client::unix(&sock);

    let reply = client.call(&Request::Ping).unwrap();
    assert_eq!(reply.get("op").and_then(Json::as_str), Some("pong"));
    let reply = client.call(&Request::Shutdown).unwrap();
    assert_eq!(reply.get("stopping").and_then(Json::as_bool), Some(true));
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Two *processes'* worth of PerfDb handles on one legacy file: the
/// second save merges instead of clobbering (the old last-writer-wins
/// bug lost the first writer's tune).
#[test]
fn legacy_file_concurrent_saves_merge() {
    let dir = tmp_dir("legacy");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("perfdb.json");
    let mut writer_a = PerfDb::open(&path).unwrap();
    let mut writer_b = PerfDb::open(&path).unwrap();
    writer_a.record(entry("platform-a", "axpy", "n4096", "a_cfg", 100));
    writer_b.record(entry("platform-b", "axpy", "n4096", "b_cfg", 200));
    writer_a.save().unwrap();
    writer_b.save().unwrap();
    let merged = PerfDb::open(&path).unwrap();
    assert_eq!(merged.len(), 2);
    assert_eq!(merged.lookup("platform-a", "axpy", "n4096").unwrap().best_config_id, "a_cfg");
    assert_eq!(merged.lookup("platform-b", "axpy", "n4096").unwrap().best_config_id, "b_cfg");
    std::fs::remove_dir_all(&dir).ok();
}

/// Migration: a v1 file becomes shards; the daemon serves them.
#[test]
fn migrated_legacy_db_serves_lookups() {
    let dir = tmp_dir("migrated");
    std::fs::create_dir_all(&dir).unwrap();
    let legacy_path = dir.join("perfdb.json");
    let mut legacy = PerfDb::open(&legacy_path).unwrap();
    legacy.record(entry("old-box", "axpy", "n4096", "legacy_cfg", 1_700_000_000));
    legacy.save().unwrap();

    let db = ShardedDb::open(dir.join("shards")).unwrap();
    assert_eq!(db.import_legacy(&legacy_path).unwrap(), 1);

    let server = Server::new(db, fp(1024, &["avx2"]), ServeOpts::default());
    let reply = server.handle_request(&Request::Lookup {
        platform: Some("old-box".into()),
        kernel: "axpy".into(),
        workload: "n4096".into(),
    });
    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
    assert_eq!(
        reply.get("entry").and_then(|e| e.get("best_config_id")).and_then(Json::as_str),
        Some("legacy_cfg")
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Staleness: TTL-expired entries surface through `retune-next`.
#[test]
fn stale_entries_flow_to_retune_queue() {
    let dir = tmp_dir("stale");
    let db = ShardedDb::open(&dir).unwrap();
    db.record(None, entry("aging-box", "axpy", "n4096", "old_cfg", 1000)).unwrap();
    db.record(None, entry("aging-box", "dot", "n4096", "old_cfg2", 1000)).unwrap();
    let fresh = entry("fresh-box", "axpy", "n4096", "new_cfg", unix_now());
    db.record(None, fresh).unwrap();

    let server = Server::new(
        db,
        fp(1024, &["avx2"]),
        ServeOpts { ttl_s: 3600, ..ServeOpts::default() },
    );
    assert_eq!(server.scan_once().unwrap(), 2, "both aged frontiers queue; fresh does not");
    let mut seen = Vec::new();
    loop {
        let reply = server.handle_request(&Request::RetuneNext);
        if reply.get("found").and_then(Json::as_bool) != Some(true) {
            break;
        }
        assert!(
            reply.get("lease_id").and_then(Json::as_u64).is_some(),
            "retune-next is a lease now; the reply must carry the lease id"
        );
        let task = reply.get("task").unwrap();
        assert_eq!(task.get("reason").and_then(Json::as_str), Some("ttl-expired"));
        seen.push(task.get("kernel").and_then(Json::as_str).unwrap().to_string());
    }
    seen.sort();
    assert_eq!(seen, vec!["axpy".to_string(), "dot".to_string()]);
    std::fs::remove_dir_all(&dir).ok();
}

fn test_portfolio(kernel: &str, built_at: u64) -> Portfolio {
    Portfolio {
        kernel: kernel.into(),
        strategy: "greedy-cover".into(),
        k_max: 4,
        retained: 0.95,
        built_at,
        feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
        items: vec![PortfolioItem {
            config: [
                ("loop_order".to_string(), 1i64),
                ("tile_m".to_string(), 32i64),
                ("tile_n".to_string(), 32i64),
                ("unroll".to_string(), 4i64),
            ]
            .into_iter()
            .collect(),
            config_id: "o1_tm32_tn32_u4".into(),
            centroid: vec![5.0; FEATURE_NAMES.len()],
            covered: vec!["m32n32k32".into()],
        }],
    }
}

/// Two workers drain one queue concurrently over real TCP: every task
/// is executed exactly once — the lease checkout makes double
/// execution impossible — and the counters agree.
#[test]
fn two_workers_drain_queue_without_double_execution() {
    let dir = tmp_dir("two-workers");
    let db = ShardedDb::open(&dir).unwrap();
    // 10 stale artifact-kernel frontiers across two platforms.
    for (p, kernel) in [("box-a", "axpy"), ("box-b", "dot")] {
        for i in 0..5 {
            db.record(None, entry(p, kernel, &format!("n{}", 1 << i), "old", 1000)).unwrap();
        }
    }
    let server = Arc::new(Server::new(
        db,
        fp(1024, &["avx2"]),
        ServeOpts { ttl_s: 3600, ..ServeOpts::default() },
    ));
    assert_eq!(server.scan_once().unwrap(), 10);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = Arc::clone(&server);
    let serve_thread = std::thread::spawn(move || srv.run_tcp(listener).unwrap());

    let executed: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let mut drainers = Vec::new();
    for _ in 0..2 {
        let addr = addr.clone();
        let executed = Arc::clone(&executed);
        drainers.push(std::thread::spawn(move || {
            let client = Client::tcp(addr);
            loop {
                let Some(leased) = client.lease_task(None, None, Some(60)).unwrap() else {
                    break;
                };
                // "Execute": record the task identity, then settle.
                let t = &leased.task;
                executed.lock().unwrap().push(format!(
                    "{}|{}|{}|{}",
                    t.kind.as_str(),
                    t.platform_key,
                    t.kernel,
                    t.tag.clone().unwrap_or_default()
                ));
                assert!(client.complete_task(leased.lease_id).unwrap());
            }
        }));
    }
    for d in drainers {
        d.join().unwrap();
    }
    let mut executed = executed.lock().unwrap().clone();
    let total = executed.len();
    executed.sort();
    executed.dedup();
    assert_eq!(total, 10, "both workers together execute every task");
    assert_eq!(executed.len(), 10, "no task is executed twice");
    let stats = server.stats();
    assert_eq!(stats.tasks_leased, 10);
    assert_eq!(stats.tasks_completed, 10);
    assert_eq!(stats.tasks_pending, 0);
    assert_eq!(stats.tasks_inflight, 0);

    let client = Client::tcp(addr);
    client.call(&Request::Shutdown).unwrap();
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance-criteria loop, hermetically: a daemon holding a
/// stale portfolio plus an external worker converge without operator
/// action.  The staleness scan queues a portfolio-rebuild task, the
/// worker leases and executes it (real quick sweep + rebuild), reports
/// through record/record-portfolio, and a subsequent `portfolio` query
/// serves the rebuilt result with a fresh `built_at`.
#[test]
fn worker_rebuilds_stale_portfolio_end_to_end() {
    let dir = tmp_dir("worker-e2e");
    let db = ShardedDb::open(&dir).unwrap();
    // The worker only leases tasks for its own platform, so the stale
    // portfolio must live under the test machine's real key.
    let host = Fingerprint::detect();
    db.record_portfolio(&host.key(), Some(&host), test_portfolio("gemm", 1000)).unwrap();

    let server = Arc::new(Server::new(db, host.clone(), ServeOpts::default()));
    assert_eq!(server.scan_once().unwrap(), 1, "aged built_at queues one rebuild");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = Arc::clone(&server);
    let serve_thread = std::thread::spawn(move || srv.run_tcp(listener).unwrap());

    let worker = Worker::new(
        Client::tcp(addr.clone()),
        WorkerOpts { quick: true, ..WorkerOpts::default() },
    );
    let report = worker.run_once().unwrap().expect("a rebuild task was queued");
    assert!(report.ok, "rebuild failed: {}", report.detail);
    assert_eq!(report.task.kind, TaskKind::PortfolioRebuild);

    // The daemon now serves the rebuilt portfolio — fresh built_at,
    // cache invalidated, no TTL wait.
    let client = Client::tcp(addr);
    let reply = client
        .call(&Request::Portfolio {
            platform: Some(host.key()),
            kernel: "gemm".into(),
            dims: None,
            fingerprint: None,
        })
        .unwrap();
    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
    assert_eq!(reply.get("source").and_then(Json::as_str), Some("exact"));
    let built_at = reply
        .get("portfolio")
        .and_then(|p| p.get("built_at"))
        .and_then(Json::as_u64)
        .unwrap();
    assert!(built_at > 1000, "built_at must advance past the aged stamp");
    let stats = server.stats();
    assert_eq!(stats.tasks_completed, 1);
    assert_eq!(stats.tasks_pending, 0);
    // The sweep history was recorded too (lookups will find entries).
    assert!(stats.records >= 2, "rebuild reports sweep entries + portfolio");
    // Converged: the next scan finds nothing stale.
    assert_eq!(server.scan_once().unwrap(), 0);

    client.call(&Request::Shutdown).unwrap();
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Killing a worker mid-lease loses nothing: the lease expires after
/// its TTL and the task requeues for the next worker.
#[test]
fn killed_worker_mid_lease_requeues_after_ttl() {
    let dir = tmp_dir("dead-worker");
    let db = ShardedDb::open(&dir).unwrap();
    db.record(None, entry("aging-box", "axpy", "n4096", "old", 1000)).unwrap();
    let server = Server::new(
        db,
        fp(1024, &["avx2"]),
        ServeOpts { ttl_s: 3600, ..ServeOpts::default() },
    );
    assert_eq!(server.scan_once().unwrap(), 1);
    // "Worker" leases with a 1-second TTL and then dies silently.
    let reply = server.handle_request(&Request::TaskLease {
        kind: None,
        platform: None,
        ttl_s: Some(1),
    });
    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
    let dead_lease = reply.get("lease_id").and_then(Json::as_u64).unwrap();
    // Nothing to lease while the task is in flight.
    let reply = server.handle_request(&Request::TaskLease {
        kind: None,
        platform: None,
        ttl_s: Some(1),
    });
    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(false));
    // Past the TTL, the next queue touch requeues it for a live worker.
    std::thread::sleep(std::time::Duration::from_millis(2100));
    let reply = server.handle_request(&Request::TaskLease {
        kind: None,
        platform: None,
        ttl_s: Some(60),
    });
    assert_eq!(
        reply.get("found").and_then(Json::as_bool),
        Some(true),
        "the dead worker's task must requeue after its lease TTL"
    );
    let new_lease = reply.get("lease_id").and_then(Json::as_u64).unwrap();
    assert_ne!(dead_lease, new_lease);
    let stats = server.stats();
    assert_eq!(stats.leases_expired, 1);
    // The dead worker's late heartbeat learns the lease is gone.
    let reply = server.handle_request(&Request::TaskHeartbeat { lease_id: dead_lease });
    assert_eq!(reply.get("extended").and_then(Json::as_bool), Some(false));
    std::fs::remove_dir_all(&dir).ok();
}

fn start_pool_server(
    dir: &std::path::Path,
    opts: ServeOpts,
) -> (Arc<Server>, String, std::thread::JoinHandle<()>) {
    let db = ShardedDb::open(dir).unwrap();
    let server = Arc::new(Server::new(db, fp(1024, &["avx2"]), opts));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = Arc::clone(&server);
    let serve_thread = std::thread::spawn(move || srv.run_tcp(listener).unwrap());
    (server, addr, serve_thread)
}

/// More concurrent clients than pool workers: the accept queue absorbs
/// the overflow and every request is answered — a fixed pool is a
/// throughput bound, not a correctness one.
#[test]
fn worker_pool_serves_more_clients_than_workers() {
    let dir = tmp_dir("pool-width");
    let (_server, addr, serve_thread) =
        start_pool_server(&dir, ServeOpts { workers: 2, ..ServeOpts::default() });

    let mut handles = Vec::new();
    for _ in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..5 {
                let reply = Client::tcp(addr.clone()).call(&Request::Ping).unwrap();
                assert_eq!(reply.get("op").and_then(Json::as_str), Some("pong"));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let client = Client::tcp(addr);
    client.call(&Request::Shutdown).unwrap();
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// A connection already accepted (queued behind a busy worker) is
/// still served after shutdown is requested: workers drain the queue
/// before exiting instead of abandoning accepted clients.
#[test]
fn graceful_shutdown_drains_queued_connections() {
    let dir = tmp_dir("pool-drain");
    let (server, addr, serve_thread) =
        start_pool_server(&dir, ServeOpts { workers: 1, ..ServeOpts::default() });

    // Pin the single worker with a held-open connection.
    let mut held = std::net::TcpStream::connect(&addr).unwrap();
    held.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    held.flush().unwrap();
    let mut reader = BufReader::new(held.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"), "worker must be attached: {line}");

    // Queue a second client behind it, then stop accepting while the
    // second connection is still waiting for a worker.
    let queued = std::thread::spawn({
        let addr = addr.clone();
        move || Client::tcp(addr).call(&Request::Ping).unwrap()
    });
    std::thread::sleep(std::time::Duration::from_millis(300));
    server.request_shutdown();
    std::thread::sleep(std::time::Duration::from_millis(100));
    drop(reader);
    drop(held);

    let reply = queued.join().unwrap();
    assert_eq!(
        reply.get("op").and_then(Json::as_str),
        Some("pong"),
        "a queued connection must drain through the pool on graceful shutdown"
    );
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Clients killed mid-request — a half-written line, a peer that dies
/// before reading its reply — must not wedge pool workers: the same
/// fixed pool keeps answering afterwards.
#[test]
fn killed_client_mid_request_does_not_wedge_the_pool() {
    let dir = tmp_dir("pool-kill");
    let (_server, addr, serve_thread) =
        start_pool_server(&dir, ServeOpts { workers: 2, ..ServeOpts::default() });

    for i in 0..6 {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        if i % 2 == 0 {
            // Partial request: the newline never arrives.
            s.write_all(b"{\"op\":\"lookup\"").unwrap();
        } else {
            // Full request, but the peer vanishes before the reply.
            s.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        }
        drop(s);
    }

    // Both workers must chew through the corpses and still answer; a
    // wedged worker would halve the pool, two would hang this client.
    let client = Client::tcp(addr);
    for _ in 0..4 {
        let reply = client.call(&Request::Ping).unwrap();
        assert_eq!(reply.get("op").and_then(Json::as_str), Some("pong"));
    }
    client.call(&Request::Shutdown).unwrap();
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// `--max-conns` counts queued connections too: with the one worker
/// busy and the queue holding a second connection, the third is shed
/// with the retryable `overloaded` reply (PR 6 semantics, preserved
/// across the pool refactor), and capacity frees as holders leave.
#[test]
fn pool_sheds_at_max_conns_counting_queued_connections() {
    let dir = tmp_dir("pool-shed");
    let (server, addr, serve_thread) = start_pool_server(
        &dir,
        ServeOpts { workers: 1, max_conns: 2, ..ServeOpts::default() },
    );

    let hold_a = std::net::TcpStream::connect(&addr).unwrap();
    let hold_b = std::net::TcpStream::connect(&addr).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(300)); // both accepted

    let one_shot = Client::tcp(addr.clone())
        .with_policy(RetryPolicy { attempts: 1, ..RetryPolicy::default() });
    let err = one_shot.call(&Request::Ping).unwrap_err();
    assert!(format!("{err:#}").contains("overloaded"), "want a shed reply, got: {err:#}");

    drop(hold_a);
    drop(hold_b);
    std::thread::sleep(std::time::Duration::from_millis(500)); // handlers drain
    let client = Client::tcp(addr);
    let reply = client.call(&Request::Ping).unwrap();
    assert_eq!(reply.get("op").and_then(Json::as_str), Some("pong"));
    assert!(server.stats().conns_shed >= 1);

    client.call(&Request::Shutdown).unwrap();
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
