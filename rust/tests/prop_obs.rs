//! Adversarial properties of the telemetry histogram.
//!
//! The unit tests in `obs/hist.rs` check hand-picked examples; these
//! tests check the *space*: bucket containment over a wide pseudo-random
//! value sweep, merge algebra (associative, commutative, lossless),
//! quantile monotonicity in q, and lock-free recording under real
//! thread contention losing nothing.

use portatune::obs::hist::{Histogram, N_BINS};

/// Deterministic 64-bit LCG (Knuth's MMIX constants) — no external rng
/// crates, reproducible failures.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }
}

/// Values spanning every magnitude the histogram can see: exact small
/// bins, every octave boundary ±1, and pseudo-random values up to
/// `u64::MAX`.
fn adversarial_values() -> Vec<u64> {
    let mut values = vec![0, 1, 2, 3, u64::MAX];
    for shift in 2..64 {
        let v = 1u64 << shift;
        values.extend([v - 1, v, v + 1]);
    }
    let mut rng = Lcg(0x0b5e_55ed_c0ff_ee00);
    for _ in 0..2000 {
        let raw = rng.next();
        // Mask to a random width so small magnitudes are as common as
        // huge ones (raw u64s are almost always in the top octaves).
        let width = (rng.next() % 64) as u32;
        values.push(raw & (u64::MAX >> width));
    }
    values
}

#[test]
fn every_value_lands_in_a_bucket_that_contains_it() {
    for v in adversarial_values() {
        let idx = Histogram::bucket_index(v);
        assert!(idx < N_BINS, "index {idx} out of range for value {v}");
        let (lo, hi) = Histogram::bucket_bounds(idx);
        assert!(
            lo <= v && v <= hi,
            "value {v} fell in bucket {idx} [{lo}, {hi}] which does not contain it"
        );
    }
}

#[test]
fn bucket_bounds_tile_the_u64_range_without_gaps() {
    let (lo, _) = Histogram::bucket_bounds(0);
    assert_eq!(lo, 0, "the first bucket must start at 0");
    for idx in 1..N_BINS {
        let (_, prev_hi) = Histogram::bucket_bounds(idx - 1);
        let (lo, hi) = Histogram::bucket_bounds(idx);
        assert_eq!(
            lo,
            prev_hi + 1,
            "gap or overlap between bucket {} (..{prev_hi}) and {idx} ({lo}..)",
            idx - 1
        );
        assert!(lo <= hi, "inverted bucket {idx}: [{lo}, {hi}]");
    }
    let (_, last_hi) = Histogram::bucket_bounds(N_BINS - 1);
    assert_eq!(last_hi, u64::MAX, "the last bucket must reach u64::MAX");
}

#[test]
fn merge_is_commutative_associative_and_lossless() {
    let values = adversarial_values();
    let thirds: Vec<Histogram> = (0..3)
        .map(|t| {
            let h = Histogram::new();
            for (i, &v) in values.iter().enumerate() {
                if i % 3 == t {
                    h.record(v);
                }
            }
            h
        })
        .collect();

    // One histogram fed everything is the ground truth.
    let all = Histogram::new();
    for &v in &values {
        all.record(v);
    }

    // (a + b) + c == a + (b + c) == ground truth, bin for bin.
    let left = Histogram::new();
    left.merge(&thirds[0]);
    left.merge(&thirds[1]);
    left.merge(&thirds[2]);
    let right = Histogram::new();
    right.merge(&thirds[2]);
    right.merge(&thirds[1]);
    right.merge(&thirds[0]);
    assert_eq!(left.snapshot(), all.snapshot(), "merge lost or moved counts");
    assert_eq!(left.snapshot(), right.snapshot(), "merge order changed the result");
    assert_eq!(left.count(), values.len() as u64);
    assert_eq!(left.sum(), all.sum(), "merge lost sum");
    // Wrapping sums are part of the contract (u64 totals), so check
    // the parts too: each third's sum survived into the merge.
    let part_sum = thirds.iter().fold(0u64, |acc, h| acc.wrapping_add(h.sum()));
    assert_eq!(left.sum(), part_sum);
}

#[test]
fn quantiles_are_monotone_in_q() {
    let h = Histogram::new();
    let mut rng = Lcg(42);
    for _ in 0..10_000 {
        h.record(rng.next() % 1_000_000);
    }
    let qs = [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0];
    let mut prev = 0u64;
    for q in qs {
        let v = h.quantile(q);
        assert!(
            v >= prev,
            "quantile({q}) = {v} dipped below quantile at lower q ({prev})"
        );
        prev = v;
    }
    // And the bound property holds at the top: p100 is a bucket upper
    // bound for the maximum, so it can never be below the true max's
    // bucket lower bound.
    assert!(h.quantile(1.0) >= h.quantile(0.999));
}

#[test]
fn concurrent_recording_loses_nothing() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    let h = Histogram::new();
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let h = &h;
            scope.spawn(move || {
                for i in 0..PER_THREAD {
                    // Distinct per-thread values so a lost update would
                    // skew some bucket, not just the total.
                    h.record(t * PER_THREAD + i);
                }
            });
        }
    });
    assert_eq!(h.count(), THREADS * PER_THREAD, "atomic recording dropped observations");
    // Sum of 0..80000 exactly.
    let n = THREADS * PER_THREAD;
    assert_eq!(h.sum(), n * (n - 1) / 2, "atomic recording dropped sum");
    let total: u64 = h.snapshot().iter().sum();
    assert_eq!(total, n, "bins disagree with the count");
}
