//! Offline decision bundles, end to end and adversarially.
//!
//! Three contracts from the bundle design:
//!
//! 1. **Byte identity** — export → import → export reproduces every
//!    shard document byte for byte (bundles carry the on-disk shard
//!    texts verbatim, checksummed at two layers).
//! 2. **Parity** — [`Client::from_bundle`] answers every read op with
//!    *exactly* the reply a live daemon gives for the same snapshot:
//!    both shape replies through the same `ServeSnapshot` methods, so
//!    this is equality of whole JSON replies, not spot checks.
//! 3. **Rejection names the section** — in the style of
//!    `prop_audit.rs`, every payload byte flipped one at a time must
//!    pin the failing section by name, and truncation anywhere (plus a
//!    spliced-footer cover-up) is refused with a named section.

use std::collections::BTreeMap;
use std::sync::Arc;

use portatune::coordinator::perfdb::{DbEntry, ShardedDb};
use portatune::coordinator::platform::Fingerprint;
use portatune::coordinator::portfolio::{Portfolio, PortfolioItem, FEATURE_NAMES};
use portatune::service::{parse_bundle, Client, Request, ServeOpts, Server};
use portatune::util::json::Json;
use portatune::util::sha256;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("portatune-bundlert-{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn fp(l2: u64, simd: &[&str]) -> Fingerprint {
    Fingerprint {
        cpu_model: "Bundle RT CPU".into(),
        num_cpus: 8,
        simd: simd.iter().map(|s| s.to_string()).collect(),
        cache_l1d_kb: 32,
        cache_l2_kb: l2,
        cache_l3_kb: 8192,
        os: "linux".into(),
    }
}

fn entry(platform: &str, kernel: &str, tag: &str, id: &str) -> DbEntry {
    DbEntry {
        platform_key: platform.into(),
        kernel: kernel.into(),
        tag: tag.into(),
        best_params: [("block_size".to_string(), 256i64)].into_iter().collect(),
        best_config_id: id.into(),
        best_time_s: 1e-3,
        baseline_time_s: 2e-3,
        reference_time_s: 9e-4,
        evaluations: 4,
        strategy: "exhaustive".into(),
        recorded_at: 1_700_000_000,
    }
}

fn test_portfolio(kernel: &str) -> Portfolio {
    Portfolio {
        kernel: kernel.into(),
        strategy: "greedy-cover".into(),
        k_max: 4,
        retained: 0.95,
        built_at: 1_700_000_000,
        feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
        items: vec![PortfolioItem {
            config: [
                ("loop_order".to_string(), 1i64),
                ("tile_m".to_string(), 32i64),
                ("tile_n".to_string(), 32i64),
                ("unroll".to_string(), 4i64),
            ]
            .into_iter()
            .collect(),
            config_id: "o1_tm32_tn32_u4".into(),
            centroid: vec![5.0; FEATURE_NAMES.len()],
            covered: vec!["m32n32k32".into()],
        }],
    }
}

/// A two-platform store with fingerprints and a portfolio, plus a
/// daemon over it whose `export_bundle` cuts the artifact under test.
fn seeded_server(dir: &std::path::Path) -> (ShardedDb, Server) {
    let db = ShardedDb::open(dir.join("shards")).unwrap();
    let fp1 = fp(1024, &["avx2", "fma"]);
    let fp2 = fp(512, &["sse2", "sse4_2"]);
    db.record(Some(&fp1), entry("p1", "axpy", "n4096", "cfg_p1")).unwrap();
    db.record(Some(&fp1), entry("p1", "dot", "n65536", "cfg_p1_dot")).unwrap();
    db.record(Some(&fp2), entry("p2", "axpy", "n4096", "cfg_p2")).unwrap();
    db.record_portfolio("p1", Some(&fp1), test_portfolio("gemm")).unwrap();
    let server = Server::new(db.clone(), fp(2048, &["avx2", "fma"]), ServeOpts::default());
    (db, server)
}

#[test]
fn export_import_export_is_byte_identical() {
    let dir = tmp_dir("byteid");
    let (db_a, server) = seeded_server(&dir);
    let text = server.export_bundle().unwrap();
    let (meta, shard_texts) = parse_bundle(&text).unwrap();
    assert_eq!(shard_texts.len(), 2);
    assert_eq!(meta.generation, server.stats().snapshot_gen);
    assert!(meta.fingerprint.is_some(), "the exporter freezes its fingerprint");

    // Import into a fresh store: every shard document lands verbatim.
    let db_b = ShardedDb::open(dir.join("imported")).unwrap();
    for shard_text in &shard_texts {
        db_b.import_shard_text(shard_text).unwrap();
    }
    for platform in ["p1", "p2"] {
        assert_eq!(
            db_b.export_shard_text(platform).unwrap(),
            db_a.export_shard_text(platform).unwrap(),
            "shard {platform} must survive export → import byte-identical"
        );
    }
    // Importing the same bundle again is a no-op merge, not a dup.
    for shard_text in &shard_texts {
        db_b.import_shard_text(shard_text).unwrap();
    }
    assert_eq!(db_b.load("p1").unwrap().unwrap().entries.len(), 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn offline_client_answers_equal_live_daemon_answers() {
    let dir = tmp_dir("parity");
    let (_db, server) = seeded_server(&dir);
    let server = Arc::new(server);
    let bundle_path = dir.join("perf.bundle");
    std::fs::write(&bundle_path, server.export_bundle().unwrap()).unwrap();
    let offline = Client::from_bundle(&bundle_path).unwrap();

    let dims: BTreeMap<String, i64> =
        [("m".to_string(), 128i64), ("n".to_string(), 128), ("k".to_string(), 64)]
            .into_iter()
            .collect();
    let probe_fp = fp(4096, &["avx2"]);
    let requests = vec![
        Request::Ping,
        // Exact hit, miss on an unseen workload, miss on an unseen
        // platform: all three lookup shapes.
        Request::Lookup {
            platform: Some("p1".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
        },
        Request::Lookup {
            platform: Some("p1".into()),
            kernel: "axpy".into(),
            workload: "n9999".into(),
        },
        Request::Lookup {
            platform: Some("nobody".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
        },
        // Deploy: exact, and the transfer-ranked miss for a platform
        // the store has never seen.
        Request::Deploy {
            platform: Some("p1".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: Some(probe_fp.clone()),
        },
        Request::Deploy {
            platform: Some("fresh-box".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: Some(probe_fp.clone()),
        },
        // Portfolio: exact with dim selection, transfer, total miss.
        Request::Portfolio {
            platform: Some("p1".into()),
            kernel: "gemm".into(),
            dims: Some(dims),
            fingerprint: None,
        },
        Request::Portfolio {
            platform: Some("fresh-box".into()),
            kernel: "gemm".into(),
            dims: None,
            fingerprint: Some(probe_fp),
        },
        Request::Portfolio {
            platform: Some("p1".into()),
            kernel: "nope".into(),
            dims: None,
            fingerprint: None,
        },
    ];
    for req in &requests {
        let live = server.handle_request(req);
        let off = offline.call(req).unwrap();
        assert_eq!(off, live, "offline and live replies must be identical for {req:?}");
    }

    // Spot-check the suite exercised real paths, not nine misses.
    let transfer = server.handle_request(&requests[5]);
    assert_eq!(transfer.get("source").and_then(Json::as_str), Some("transfer"));
    assert!(transfer.get("count").and_then(Json::as_u64).unwrap() > 0);
    let selected = server.handle_request(&requests[6]);
    assert_eq!(selected.get("found").and_then(Json::as_bool), Some(true));
    assert!(selected.get("selected").is_some(), "dims must drive member selection");

    // Ops that need daemon state are definitive errors offline, with
    // the op named.
    let err = offline.call(&Request::Stats).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("requires a daemon") && msg.contains("stats"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Payload byte ranges of a pristine bundle: (section name, start, end).
fn payload_ranges(text: &str) -> Vec<(String, usize, usize)> {
    let mut ranges = Vec::new();
    let mut pos = text.find('\n').unwrap() + 1;
    let bytes = text.as_bytes();
    while pos < bytes.len() {
        let line_end = pos + text[pos..].find('\n').unwrap();
        let line = &text[pos..line_end];
        if let Some(rest) = line.strip_prefix("section ") {
            let mut parts = rest.split(' ');
            let name = parts.next().unwrap().to_string();
            let len: usize = parts.next().unwrap().parse().unwrap();
            ranges.push((name, line_end + 1, line_end + 1 + len));
            pos = line_end + 1 + len + 1;
        } else {
            break; // footer
        }
    }
    ranges
}

#[test]
fn every_flipped_byte_is_rejected_and_payload_flips_name_their_section() {
    let dir = tmp_dir("flip");
    let (_db, server) = seeded_server(&dir);
    let text = server.export_bundle().unwrap();
    assert!(parse_bundle(&text).is_ok(), "pristine bundle must verify");
    let ranges = payload_ranges(&text);
    assert_eq!(ranges.len(), 3, "meta + two shards");

    let bytes = text.as_bytes();
    for p in 0..bytes.len() {
        let mut flipped = bytes.to_vec();
        flipped[p] ^= 0x01; // ASCII-safe: the bundle text stays UTF-8
        let flipped = String::from_utf8(flipped).unwrap();
        let err = parse_bundle(&flipped)
            .expect_err(&format!("flip of byte {p} went undetected"));
        let msg = format!("{err:#}");
        assert!(msg.contains("bundle"), "flip at {p}: unnamed rejection: {msg}");
        if let Some((name, _, _)) =
            ranges.iter().find(|(_, start, end)| p >= *start && p < *end)
        {
            assert!(
                msg.contains(name.as_str()),
                "flip at {p} inside {name} payload pinned the wrong section: {msg}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncation_anywhere_is_rejected_with_a_named_section() {
    let dir = tmp_dir("trunc");
    let (_db, server) = seeded_server(&dir);
    let text = server.export_bundle().unwrap();

    // Cut at every line boundary and at every mid-line point between
    // boundaries: nothing short of the full file may verify.
    let mut cuts = vec![0usize];
    cuts.extend(text.bytes().enumerate().filter(|(_, b)| *b == b'\n').map(|(i, _)| i + 1));
    for w in cuts.windows(2) {
        let (boundary, next) = (w[0], w[1]);
        for cut in [boundary, boundary + (next - boundary) / 2] {
            if cut == text.len() {
                continue;
            }
            let err = parse_bundle(&text[..cut])
                .expect_err(&format!("truncation at byte {cut} verified"));
            let msg = format!("{err:#}");
            assert!(msg.contains("bundle"), "cut at {cut}: unnamed rejection: {msg}");
        }
    }

    // The cover-up: drop the whole trailing shard section AND splice a
    // recomputed, self-consistent footer.  The meta's declared shard
    // count still names the lie.
    let ranges = payload_ranges(&text);
    let (last_name, _, _) = ranges.last().unwrap().clone();
    assert_eq!(last_name, "shard1");
    let section_line_start = text.find("\nsection shard1 ").unwrap() + 1;
    let spliced = format!(
        "{}end {}\n",
        &text[..section_line_start],
        sha256::hex_digest(text[..section_line_start].as_bytes())
    );
    let err = parse_bundle(&spliced).expect_err("spliced footer verified");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("declares 2 shards, found 1"),
        "the declared count must catch whole-section removal: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
