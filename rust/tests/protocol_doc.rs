//! docs/PROTOCOL.md is executable documentation: every `C:` example
//! line must parse as a wire request (and round-trip through the
//! serializer), every `S:` line must parse as a reply JSON object with
//! the `ok` discriminant, and the examples must cover every op the
//! parser knows.  If an op is added, renamed, or its fields change,
//! either the spec or this test fails — the two cannot drift apart.

use portatune::service::Request;
use portatune::util::json::{self, Json};

fn spec_text() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/PROTOCOL.md");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {path}: {e} — did docs/PROTOCOL.md move?"))
}

fn example_lines(prefix: &str) -> Vec<String> {
    spec_text()
        .lines()
        .map(str::trim)
        .filter_map(|l| l.strip_prefix(prefix).map(str::to_string))
        .collect()
}

#[test]
fn every_documented_request_parses_and_round_trips() {
    let requests = example_lines("C: ");
    assert!(!requests.is_empty(), "PROTOCOL.md has no C: example lines");
    for line in &requests {
        let parsed = Request::parse_line(line)
            .unwrap_or_else(|e| panic!("documented request does not parse: {line}\n  {e:#}"));
        let wire = parsed.to_line();
        let reparsed = Request::parse_line(&wire)
            .unwrap_or_else(|e| panic!("serialized form does not re-parse: {wire}\n  {e:#}"));
        assert_eq!(
            reparsed.to_line(),
            wire,
            "serializer is not a fixed point for documented request: {line}"
        );
    }
}

#[test]
fn every_documented_reply_is_a_valid_reply_object() {
    let replies = example_lines("S: ");
    assert!(!replies.is_empty(), "PROTOCOL.md has no S: example lines");
    for line in &replies {
        let v = json::parse(line)
            .unwrap_or_else(|e| panic!("documented reply does not parse: {line}\n  {e}"));
        assert!(
            v.get("ok").and_then(Json::as_bool).is_some(),
            "documented reply lacks the ok discriminant: {line}"
        );
    }
}

#[test]
fn examples_cover_every_op() {
    let mut documented: Vec<String> = example_lines("C: ")
        .iter()
        .map(|line| {
            json::parse(line)
                .expect("C: lines are JSON")
                .get("op")
                .and_then(Json::as_str)
                .expect("C: lines carry an op")
                .to_string()
        })
        .collect();
    documented.sort();
    documented.dedup();
    let mut expected = vec![
        "deploy",
        "lookup",
        "metrics",
        "ping",
        "portfolio",
        "record",
        "record-portfolio",
        "report",
        "retune-next",
        "shutdown",
        "stats",
        "task-complete",
        "task-fail",
        "task-heartbeat",
        "task-lease",
    ];
    expected.sort_unstable();
    assert_eq!(
        documented, expected,
        "PROTOCOL.md must document exactly the ops the parser knows"
    );
}

/// The documented stats surface cannot drift from the implemented one:
/// every key `serve_stats_json` emits must appear in the spec's `stats`
/// reply example, and the spec must not promise keys the daemon no
/// longer sends.  The `metrics` op's `counters` object is the same
/// payload, so both documented copies are checked.
#[test]
fn documented_stats_keys_match_serve_stats_json() {
    use portatune::report::serve_stats_json;
    use portatune::service::ServeStats;
    use std::collections::BTreeSet;

    let implemented: BTreeSet<String> = match serve_stats_json(&ServeStats::default()) {
        Json::Obj(map) => map.into_keys().collect(),
        other => panic!("serve_stats_json is not an object: {other:?}"),
    };

    let mut checked = 0;
    for line in example_lines("S: ") {
        let v = json::parse(&line).expect("example lines are JSON");
        for payload_key in ["stats", "counters"] {
            let Some(Json::Obj(map)) = v.get(payload_key) else { continue };
            let documented: BTreeSet<String> = map.keys().cloned().collect();
            assert_eq!(
                documented, implemented,
                "the documented `{payload_key}` object has drifted from \
                 serve_stats_json — update docs/PROTOCOL.md or report::stats"
            );
            checked += 1;
        }
    }
    assert!(checked >= 2, "spec lost its stats/counters payload examples");
}

/// The documented `report` payload cannot drift from the implemented
/// one: a real snapshot (one shard with a ledger cell, one flagged
/// regression) answers `report_reply`, and every object level — the
/// report envelope, the per-kernel row, the totals, the regression
/// listing — must carry exactly the keys the spec's example shows.
#[test]
fn documented_report_payload_matches_report_reply() {
    use portatune::coordinator::ledger::LedgerDelta;
    use portatune::coordinator::perfdb::Shard;
    use portatune::service::ServeSnapshot;
    use std::collections::{BTreeSet, HashSet};

    let mut shard = Shard {
        platform_key: "doc-box".into(),
        fingerprint: None,
        entries: Vec::new(),
        portfolios: Vec::new(),
        ledger: Default::default(),
    };
    shard.ledger.apply(&LedgerDelta {
        kernel: "axpy".into(),
        spend_ms: 1000,
        benefit_ms: 250,
        invocations: 5,
        at: 100,
    });
    let flagged: HashSet<_> =
        [("doc-box".to_string(), "axpy".to_string(), "n4096".to_string())].into();
    let live = ServeSnapshot::build(vec![shard], 7)
        .with_regressions(flagged)
        .report_reply(None);

    let keys = |v: &Json| -> BTreeSet<String> {
        match v {
            Json::Obj(map) => map.keys().cloned().collect(),
            other => panic!("expected an object, got {other:?}"),
        }
    };
    // (mandatory key sets, regression-row keys when the example shows one)
    let shape = |v: &Json| -> ([BTreeSet<String>; 3], Option<BTreeSet<String>>) {
        let report = v.get("report").expect("report replies carry a report payload");
        let platform = report.get("platforms").and_then(Json::as_arr).and_then(|a| a.first())
            .expect("report payload lists at least one platform");
        let kernel = platform.get("kernels").and_then(Json::as_arr).and_then(|a| a.first())
            .expect("platform listing carries at least one kernel row");
        let regression =
            report.get("regressions").and_then(Json::as_arr).and_then(|a| a.first()).map(&keys);
        (
            [
                keys(report),
                keys(kernel),
                keys(report.get("totals").expect("report payload carries totals")),
            ],
            regression,
        )
    };

    let (implemented, implemented_regression) = shape(&live);
    let implemented_regression =
        implemented_regression.expect("the live snapshot carries a flagged key");
    let mut checked = 0;
    let mut regression_rows = 0;
    for line in example_lines("S: ") {
        let v = json::parse(&line).expect("example lines are JSON");
        if v.get("report").is_none() {
            continue;
        }
        let (documented, regression) = shape(&v);
        assert_eq!(
            documented, implemented,
            "the documented report payload has drifted from \
             ServeSnapshot::report_reply — update docs/PROTOCOL.md or snapshot.rs"
        );
        if let Some(regression) = regression {
            assert_eq!(regression, implemented_regression, "regression row drifted");
            regression_rows += 1;
        }
        checked += 1;
    }
    assert!(checked >= 1, "spec lost its report payload example");
    assert!(regression_rows >= 1, "spec lost its regression-row example");
}

/// Generation echoes cannot drift out of the spec: every documented
/// reply on the snapshot path — the three read ops and the two record
/// acks — must carry the snapshot generation as an unsigned `gen`
/// field.  (Task-queue ops do not read the snapshot and carry none.)
/// The spec is walked in order so each `S:` line is attributed to the
/// `C:` op it answers.
#[test]
fn documented_snapshot_replies_echo_a_generation() {
    const SNAPSHOT_OPS: [&str; 6] =
        ["lookup", "deploy", "portfolio", "record", "record-portfolio", "report"];
    let mut with_gen = 0;
    let mut last_op = String::new();
    for line in spec_text().lines().map(str::trim) {
        if let Some(req) = line.strip_prefix("C: ") {
            last_op = json::parse(req)
                .expect("C: lines are JSON")
                .get("op")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string();
        } else if let Some(reply) = line.strip_prefix("S: ") {
            let v = json::parse(reply).expect("S: lines are JSON");
            // Error replies (including the overload shed) are shaped
            // before a snapshot is consulted and carry no generation.
            if v.get("ok").and_then(Json::as_bool) != Some(true) {
                continue;
            }
            if SNAPSHOT_OPS.contains(&last_op.as_str()) {
                assert!(
                    v.get("gen").and_then(Json::as_u64).is_some(),
                    "a documented {last_op} reply must echo its snapshot \
                     generation as `gen`: {line}"
                );
                with_gen += 1;
            }
        }
    }
    assert!(with_gen >= 4, "spec lost its generation-echo examples ({with_gen} found)");
}

/// The bundle format section must pin the real on-disk magic, and the
/// writer/parser pair must agree with the spec's framing: a minimal
/// exported bundle starts with the documented magic line and
/// round-trips through `parse_bundle`.
#[test]
fn documented_bundle_format_matches_the_implementation() {
    use portatune::service::{parse_bundle, write_bundle, BundleMeta, BUNDLE_MAGIC};

    let spec = spec_text();
    assert!(
        spec.contains(BUNDLE_MAGIC),
        "docs/PROTOCOL.md must document the bundle magic line {BUNDLE_MAGIC:?}"
    );
    for section in ["meta", "shard0", "footer"] {
        assert!(
            spec.contains(section),
            "the bundle spec must name the {section} section rejection surface"
        );
    }

    let meta = BundleMeta { platform: "doc-box".into(), generation: 3, fingerprint: None };
    let text = write_bundle(&meta, &[]);
    assert!(
        text.starts_with(BUNDLE_MAGIC),
        "an exported bundle must begin with the documented magic"
    );
    let (parsed, shards) = parse_bundle(&text).expect("a writer-produced bundle parses");
    assert_eq!(parsed.platform, "doc-box");
    assert_eq!(parsed.generation, 3);
    assert!(shards.is_empty());
}

/// Documented entry/fingerprint payloads must satisfy the typed
/// parsers, not just the JSON grammar — a schema change to DbEntry or
/// Fingerprint has to be reflected in the spec.
#[test]
fn documented_payloads_satisfy_typed_parsers() {
    use portatune::coordinator::perfdb::DbEntry;
    use portatune::coordinator::platform::Fingerprint;
    use portatune::coordinator::portfolio::Portfolio;
    use portatune::service::TuningTask;
    let mut entries = 0;
    let mut fingerprints = 0;
    let mut portfolios = 0;
    let mut tasks = 0;
    for line in example_lines("C: ").into_iter().chain(example_lines("S: ")) {
        let v = json::parse(&line).expect("example lines are JSON");
        if let Some(e) = v.get("entry") {
            DbEntry::from_json(e).unwrap_or_else(|err| {
                panic!("documented entry does not satisfy DbEntry::from_json: {err:#}\n{line}")
            });
            entries += 1;
        }
        if let Some(f) = v.get("fingerprint") {
            assert!(
                Fingerprint::from_json(f).is_some(),
                "documented fingerprint does not satisfy Fingerprint::from_json: {line}"
            );
            fingerprints += 1;
        }
        if let Some(p) = v.get("portfolio") {
            Portfolio::from_json(p).unwrap_or_else(|err| {
                panic!("documented portfolio does not parse: {err:#}\n{line}")
            });
            portfolios += 1;
        }
        if let Some(t) = v.get("task") {
            TuningTask::from_json(t).unwrap_or_else(|err| {
                panic!("documented task does not satisfy TuningTask::from_json: {err:#}\n{line}")
            });
            tasks += 1;
        }
    }
    assert!(entries >= 2 && fingerprints >= 2 && portfolios >= 2, "spec lost its payload examples");
    assert!(tasks >= 2, "spec lost its leased-task payload examples");
}
