//! docs/PROTOCOL.md is executable documentation: every `C:` example
//! line must parse as a wire request (and round-trip through the
//! serializer), every `S:` line must parse as a reply JSON object with
//! the `ok` discriminant, and the examples must cover every op the
//! parser knows.  If an op is added, renamed, or its fields change,
//! either the spec or this test fails — the two cannot drift apart.

use portatune::service::Request;
use portatune::util::json::{self, Json};

fn spec_text() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/PROTOCOL.md");
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {path}: {e} — did docs/PROTOCOL.md move?"))
}

fn example_lines(prefix: &str) -> Vec<String> {
    spec_text()
        .lines()
        .map(str::trim)
        .filter_map(|l| l.strip_prefix(prefix).map(str::to_string))
        .collect()
}

#[test]
fn every_documented_request_parses_and_round_trips() {
    let requests = example_lines("C: ");
    assert!(!requests.is_empty(), "PROTOCOL.md has no C: example lines");
    for line in &requests {
        let parsed = Request::parse_line(line)
            .unwrap_or_else(|e| panic!("documented request does not parse: {line}\n  {e:#}"));
        let wire = parsed.to_line();
        let reparsed = Request::parse_line(&wire)
            .unwrap_or_else(|e| panic!("serialized form does not re-parse: {wire}\n  {e:#}"));
        assert_eq!(
            reparsed.to_line(),
            wire,
            "serializer is not a fixed point for documented request: {line}"
        );
    }
}

#[test]
fn every_documented_reply_is_a_valid_reply_object() {
    let replies = example_lines("S: ");
    assert!(!replies.is_empty(), "PROTOCOL.md has no S: example lines");
    for line in &replies {
        let v = json::parse(line)
            .unwrap_or_else(|e| panic!("documented reply does not parse: {line}\n  {e}"));
        assert!(
            v.get("ok").and_then(Json::as_bool).is_some(),
            "documented reply lacks the ok discriminant: {line}"
        );
    }
}

#[test]
fn examples_cover_every_op() {
    let mut documented: Vec<String> = example_lines("C: ")
        .iter()
        .map(|line| {
            json::parse(line)
                .expect("C: lines are JSON")
                .get("op")
                .and_then(Json::as_str)
                .expect("C: lines carry an op")
                .to_string()
        })
        .collect();
    documented.sort();
    documented.dedup();
    let mut expected = vec![
        "deploy",
        "lookup",
        "metrics",
        "ping",
        "portfolio",
        "record",
        "record-portfolio",
        "retune-next",
        "shutdown",
        "stats",
        "task-complete",
        "task-fail",
        "task-heartbeat",
        "task-lease",
    ];
    expected.sort_unstable();
    assert_eq!(
        documented, expected,
        "PROTOCOL.md must document exactly the ops the parser knows"
    );
}

/// The documented stats surface cannot drift from the implemented one:
/// every key `serve_stats_json` emits must appear in the spec's `stats`
/// reply example, and the spec must not promise keys the daemon no
/// longer sends.  The `metrics` op's `counters` object is the same
/// payload, so both documented copies are checked.
#[test]
fn documented_stats_keys_match_serve_stats_json() {
    use portatune::report::serve_stats_json;
    use portatune::service::ServeStats;
    use std::collections::BTreeSet;

    let implemented: BTreeSet<String> = match serve_stats_json(&ServeStats::default()) {
        Json::Obj(map) => map.into_keys().collect(),
        other => panic!("serve_stats_json is not an object: {other:?}"),
    };

    let mut checked = 0;
    for line in example_lines("S: ") {
        let v = json::parse(&line).expect("example lines are JSON");
        for payload_key in ["stats", "counters"] {
            let Some(Json::Obj(map)) = v.get(payload_key) else { continue };
            let documented: BTreeSet<String> = map.keys().cloned().collect();
            assert_eq!(
                documented, implemented,
                "the documented `{payload_key}` object has drifted from \
                 serve_stats_json — update docs/PROTOCOL.md or report::stats"
            );
            checked += 1;
        }
    }
    assert!(checked >= 2, "spec lost its stats/counters payload examples");
}

/// Documented entry/fingerprint payloads must satisfy the typed
/// parsers, not just the JSON grammar — a schema change to DbEntry or
/// Fingerprint has to be reflected in the spec.
#[test]
fn documented_payloads_satisfy_typed_parsers() {
    use portatune::coordinator::perfdb::DbEntry;
    use portatune::coordinator::platform::Fingerprint;
    use portatune::coordinator::portfolio::Portfolio;
    use portatune::service::TuningTask;
    let mut entries = 0;
    let mut fingerprints = 0;
    let mut portfolios = 0;
    let mut tasks = 0;
    for line in example_lines("C: ").into_iter().chain(example_lines("S: ")) {
        let v = json::parse(&line).expect("example lines are JSON");
        if let Some(e) = v.get("entry") {
            DbEntry::from_json(e).unwrap_or_else(|err| {
                panic!("documented entry does not satisfy DbEntry::from_json: {err:#}\n{line}")
            });
            entries += 1;
        }
        if let Some(f) = v.get("fingerprint") {
            assert!(
                Fingerprint::from_json(f).is_some(),
                "documented fingerprint does not satisfy Fingerprint::from_json: {line}"
            );
            fingerprints += 1;
        }
        if let Some(p) = v.get("portfolio") {
            Portfolio::from_json(p).unwrap_or_else(|err| {
                panic!("documented portfolio does not parse: {err:#}\n{line}")
            });
            portfolios += 1;
        }
        if let Some(t) = v.get("task") {
            TuningTask::from_json(t).unwrap_or_else(|err| {
                panic!("documented task does not satisfy TuningTask::from_json: {err:#}\n{line}")
            });
            tasks += 1;
        }
    }
    assert!(entries >= 2 && fingerprints >= 2 && portfolios >= 2, "spec lost its payload examples");
    assert!(tasks >= 2, "spec lost its leased-task payload examples");
}
