//! Adversarial properties of the core-hour ledger and the regression
//! sentinel.
//!
//! The unit tests in `coordinator/ledger.rs` and `service/sentinel.rs`
//! check hand-picked examples; these tests check the *space*: merge
//! algebra over pseudo-random ledgers, exact integer sums under real
//! 8-thread contention through the sharded store's locked commits
//! (the `prop_obs.rs` discipline), break-even monotonicity in served
//! volume, and the sentinel's no-false-positive contract on stationary
//! streams across adversarial window boundaries.

use std::collections::BTreeMap;

use portatune::coordinator::ledger::{Ledger, LedgerDelta};
use portatune::coordinator::perfdb::ShardedDb;
use portatune::service::sentinel::{Sentinel, SentinelConfig};

/// Deterministic 64-bit LCG (Knuth's MMIX constants) — no external rng
/// crates, reproducible failures.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform in [0, 1).
    fn next_f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

fn delta(kernel: &str, spend: u64, benefit: u64, inv: u64, at: u64) -> LedgerDelta {
    LedgerDelta { kernel: kernel.into(), spend_ms: spend, benefit_ms: benefit, invocations: inv, at }
}

/// A pseudo-random ledger: a handful of kernels, bounded magnitudes so
/// sums can never overflow, timestamps that exercise the 0-sentinel in
/// `first_at`.
fn random_ledger(rng: &mut Lcg) -> Ledger {
    let mut l = Ledger::default();
    for _ in 0..(1 + rng.next() % 6) {
        let kernel = format!("k{}", rng.next() % 4);
        l.apply(&delta(
            &kernel,
            rng.next() % 1_000_000,
            rng.next() % 1_000_000,
            rng.next() % 1_000,
            rng.next() % 100, // often 0: the "never accrued" sentinel
        ));
    }
    l
}

fn join(x: &Ledger, y: &Ledger) -> Ledger {
    let mut out = x.clone();
    out.merge(y);
    out
}

#[test]
fn merge_is_commutative_associative_idempotent_and_lossless() {
    let mut rng = Lcg(0x1ed6_e21a_11_0c);
    for _ in 0..200 {
        let (a, b, c) = (random_ledger(&mut rng), random_ledger(&mut rng), random_ledger(&mut rng));
        assert_eq!(join(&a, &b), join(&b, &a), "commutative");
        assert_eq!(join(&join(&a, &b), &c), join(&a, &join(&b, &c)), "associative");
        assert_eq!(join(&a, &a), a, "idempotent");
        // Lossless: no input claim shrinks through a merge.
        let m = join(&a, &b);
        for side in [&a, &b] {
            for (kernel, cell) in &side.cells {
                let merged = m.cell(kernel).expect("merge dropped a kernel");
                assert!(merged.spend_ms >= cell.spend_ms, "merge lost spend");
                assert!(merged.benefit_ms >= cell.benefit_ms, "merge lost benefit");
                assert!(merged.invocations >= cell.invocations, "merge lost invocations");
                assert!(merged.updated_at >= cell.updated_at, "merge lost recency");
            }
        }
        // Same-lineage monotone counters merge exactly: if b extends a
        // (a's history is a prefix of b's), join(a, b) == b.
        let mut extended = a.clone();
        extended.apply(&delta("k0", 17, 5, 1, 500));
        assert_eq!(
            join(&a, &extended),
            extended,
            "a superset history must absorb its own prefix"
        );
    }
}

#[test]
fn concurrent_recording_through_the_store_sums_exactly() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 24;
    let dir = std::env::temp_dir()
        .join(format!("portatune-prop-ledger-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let db = ShardedDb::open(&dir).unwrap();
    let platform = "prop-ledger-box";

    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let db = &db;
            scope.spawn(move || {
                for i in 0..PER_THREAD {
                    // Distinct per-delta magnitudes so a lost commit
                    // would skew the totals, not just the count; two
                    // kernels so cells contend too.
                    let serial = t * PER_THREAD + i;
                    let d = delta(
                        if serial % 2 == 0 { "even" } else { "odd" },
                        serial + 1,
                        2 * (serial + 1),
                        1,
                        1_000 + serial,
                    );
                    db.apply_ledger(platform, vec![d]).unwrap();
                }
            });
        }
    });

    let shard = db.load(platform).unwrap().expect("shard must exist after accrual");
    let n = THREADS * PER_THREAD;
    let expected_spend = n * (n + 1) / 2; // 1 + 2 + … + n, exactly
    let (spend, benefit) = shard.ledger.totals();
    assert_eq!(spend, expected_spend, "locked commits dropped spend");
    assert_eq!(benefit, 2 * expected_spend, "locked commits dropped benefit");
    let cells: BTreeMap<&str, u64> = shard
        .ledger
        .cells
        .iter()
        .map(|(k, c)| (k.as_str(), c.invocations))
        .collect();
    assert_eq!(cells.get("even"), Some(&(n / 2)));
    assert_eq!(cells.get("odd"), Some(&(n / 2)));
    // Every spend-carrying delta counted as exactly one tune.
    let tunes: u64 = shard.ledger.cells.values().map(|c| c.tunes).sum();
    assert_eq!(tunes, n, "tune count disagrees with the deltas applied");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn break_even_is_monotone_in_served_volume() {
    let mut rng = Lcg(777);
    for _ in 0..50 {
        let mut l = Ledger::default();
        // A tuning run pays up front (≤ 15_000ms: the 400 serves below
        // at ≥ 50ms each are guaranteed to cover it) …
        l.apply(&delta("gemm", 10_000 + rng.next() % 5_000, 0, 0, 100));
        let mut prev_net = l.cell("gemm").unwrap().net_ms();
        let mut was_even = false;
        // … and served volume pays it back, one record at a time.
        for step in 0..400 {
            l.apply(&delta("gemm", 0, 50 + rng.next() % 500, 1 + rng.next() % 8, 200 + step));
            let cell = l.cell("gemm").unwrap();
            assert!(cell.net_ms() >= prev_net, "net position regressed as volume grew");
            prev_net = cell.net_ms();
            if was_even {
                assert!(cell.break_even(), "break-even must not un-happen under more volume");
            }
            was_even = cell.break_even();
            match cell.break_even_eta_s() {
                Some(_) => assert!(!was_even, "an even cell must not project an ETA"),
                None => {
                    // Once benefit flows, the ETA exists until even.
                    assert!(was_even || cell.benefit_ms == 0);
                }
            }
        }
        assert!(was_even, "400 serves at ≥50ms each must cover ≤15000ms spend");
    }
}

#[test]
fn sentinel_never_fires_on_stationary_streams_across_window_boundaries() {
    let cfg = SentinelConfig::default();
    let (window, min_samples) = (cfg.window, cfg.min_samples);
    let mut sentinel = Sentinel::new(cfg);
    let mut rng = Lcg(0xdead_beef);
    let base = 1.0e-3;
    // Stream lengths hugging every boundary the window logic has:
    // under/at/over min_samples, under/at/over the window size, and a
    // long soak — each on its own key, all pure ±10% stationary noise.
    let lengths = [
        1,
        min_samples - 1,
        min_samples,
        min_samples + 1,
        window - 1,
        window,
        window + 1,
        2 * window,
        2 * window + 1,
        10_000,
    ];
    for (k, &len) in lengths.iter().enumerate() {
        let tag = format!("case{k}");
        for _ in 0..len {
            let observed = base * (0.9 + 0.2 * rng.next_f64());
            let (regressing, event) =
                sentinel.observe("prop-box", "axpy", &tag, observed, base);
            assert!(!regressing, "stationary noise flagged {tag}");
            assert!(event.is_none(), "stationary noise fired an event on {tag}");
        }
        assert!(!sentinel.is_regressing("prop-box", "axpy", &tag));
    }
    assert_eq!(sentinel.active(), 0);
    assert!(sentinel.regressing_keys().is_empty());
}
