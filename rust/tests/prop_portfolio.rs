//! Property tests for the GEMM workload and the variant-portfolio
//! engine — fully hermetic (native kernels, synthetic cost matrices).
//!
//! The load-bearing invariant: a portfolio is built FROM the measured
//! matrix, so selection can never "beat" the true per-shape winner —
//! for every build shape, the cost of any portfolio member (including
//! the selected one) is ≥ the per-shape minimum by construction of the
//! clustering.  If this ever fails, the builder fabricated performance
//! that was never measured.

use std::collections::BTreeMap;

use portatune::coordinator::platform::Fingerprint;
use portatune::coordinator::portfolio::{features_for, CostMatrix, ShapePoint};
use portatune::coordinator::selection::{check_outputs, Tolerance};
use portatune::coordinator::spec::Config;
use portatune::util::rng::Rng;
use portatune::workload::gemm::{self, GemmShape};

fn fp() -> Fingerprint {
    Fingerprint {
        cpu_model: "Prop CPU".into(),
        num_cpus: 8,
        simd: vec!["avx2".into()],
        cache_l1d_kb: 32,
        cache_l2_kb: 1024,
        cache_l3_kb: 8192,
        os: "linux".into(),
    }
}

/// Random cost matrices over random shape sets: seeded, replayable.
fn random_matrix(rng: &mut Rng, nshapes: usize, nconfigs: usize) -> CostMatrix {
    let host = fp();
    let shapes: Vec<ShapePoint> = (0..nshapes)
        .map(|_| {
            let m = 1 << (3 + rng.gen_range(7)); // 8..=512
            let n = 1 << (3 + rng.gen_range(7));
            let k = 1 << (3 + rng.gen_range(7));
            let dims: BTreeMap<String, i64> = [
                ("m".to_string(), m as i64),
                ("n".to_string(), n as i64),
                ("k".to_string(), k as i64),
            ]
            .into_iter()
            .collect();
            ShapePoint {
                tag: format!("m{m}n{n}k{k}"),
                flops: (2 * m * n * k) as u64,
                features: features_for(&dims, 1.0, &host),
                dims,
            }
        })
        .collect();
    let configs: Vec<Config> = (0..nconfigs)
        .map(|c| {
            [("loop_order".to_string(), c as i64)]
                .into_iter()
                .collect()
        })
        .collect();
    let costs: Vec<Vec<f64>> = (0..nshapes)
        .map(|_| {
            (0..nconfigs)
                .map(|_| {
                    if rng.next_f64() < 0.05 {
                        f64::INFINITY // occasional gate failure
                    } else {
                        1e-4 + rng.next_f64() * 1e-2
                    }
                })
                .collect()
        })
        .collect();
    CostMatrix {
        kernel: "gemm".into(),
        shapes,
        config_ids: (0..nconfigs).map(|c| format!("c{c}")).collect(),
        configs,
        costs,
    }
}

/// The headline property: selection never picks a config whose cost on
/// a build shape beats the true per-shape winner — and therefore the
/// portfolio's retained fraction never exceeds 1.0.
#[test]
fn portfolio_never_beats_the_per_shape_winner() {
    let mut rng = Rng::new(0xF0CA);
    for case in 0..40 {
        let nshapes = 2 + rng.gen_range(8);
        let nconfigs = 2 + rng.gen_range(20);
        let matrix = random_matrix(&mut rng, nshapes, nconfigs);
        let k_max = 1 + rng.gen_range(4);
        let Ok(portfolio) = matrix.build_portfolio(k_max, 0.9) else {
            continue; // all-infinite matrices legitimately refuse
        };
        assert!(portfolio.len() <= k_max, "case {case}: size cap violated");
        assert!(
            portfolio.retained <= 1.0 + 1e-12,
            "case {case}: retained {} > 1 — portfolio 'beat' measured per-shape tuning",
            portfolio.retained
        );
        for (s, shape) in matrix.shapes.iter().enumerate() {
            let Some((_, best)) = matrix.best_for_shape(s) else { continue };
            // Every member's measured cost on this shape is >= best.
            for item in &portfolio.items {
                let col = matrix
                    .config_ids
                    .iter()
                    .position(|id| *id == item.config_id)
                    .expect("portfolio members come from the matrix");
                assert!(
                    matrix.costs[s][col] >= best - 1e-15,
                    "case {case}: member {} beats the winner on {}",
                    item.config_id,
                    shape.tag
                );
            }
            // ...including the one the deploy selector picks.
            let selected = portfolio.select(&shape.features).expect("non-empty portfolio");
            let col = matrix
                .config_ids
                .iter()
                .position(|id| *id == selected.config_id)
                .unwrap();
            assert!(matrix.costs[s][col] >= best - 1e-15, "case {case}: selection beat tuning");
        }
    }
}

/// Retention grows (weakly) with the portfolio size cap.
#[test]
fn retention_is_monotone_in_k() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..20 {
        let matrix = random_matrix(&mut rng, 2 + rng.gen_range(6), 3 + rng.gen_range(12));
        let mut last = 0.0;
        for k in 1..=4 {
            let Ok(p) = matrix.build_portfolio(k, 1.1) else { continue };
            assert!(
                p.retained + 1e-12 >= last,
                "k={k}: retention dropped from {last} to {}",
                p.retained
            );
            last = p.retained;
        }
    }
}

/// Members only ever cover shapes they actually win within the
/// portfolio, and every covered tag exists in the sweep.
#[test]
fn coverage_partitions_the_build_shapes() {
    let mut rng = Rng::new(0xC0FE);
    for _ in 0..20 {
        let matrix = random_matrix(&mut rng, 3 + rng.gen_range(6), 4 + rng.gen_range(10));
        let Ok(p) = matrix.build_portfolio(3, 1.1) else { continue };
        let tags: Vec<&str> = matrix.shapes.iter().map(|s| s.tag.as_str()).collect();
        let mut covered_total = 0;
        for item in &p.items {
            assert!(!item.covered.is_empty(), "memberless items must be dropped");
            covered_total += item.covered.len();
            for tag in &item.covered {
                assert!(tags.contains(&tag.as_str()), "unknown covered tag {tag}");
            }
        }
        // Each shape with any finite cost among members is covered
        // exactly once.
        assert!(covered_total <= matrix.shapes.len());
    }
}

/// GEMM correctness across the whole schedule space on shapes chosen
/// to stress tile-edge handling: odd primes, degenerate dims, and
/// rectangles bigger than every tile value.
#[test]
fn gemm_variants_match_reference_on_awkward_shapes() {
    let tol = Tolerance::default();
    let shapes = [
        GemmShape::new(1, 1, 1),
        GemmShape::new(2, 3, 1),
        GemmShape::new(7, 7, 7),
        GemmShape::new(31, 9, 13),
        GemmShape::new(9, 31, 13),
        GemmShape::new(129, 5, 33), // one past a tile boundary
        GemmShape::new(40, 129, 17),
    ];
    let spec = gemm::space();
    for shape in shapes {
        let (a, b) = gemm::inputs(shape, 0xA11CE);
        let want = gemm::reference(&a, &b, shape);
        for config in gemm::configs() {
            let got = gemm::run_config(&a, &b, shape, &config);
            let report = check_outputs(&got, &want, tol);
            assert!(
                report.ok,
                "{} on {}: {} mismatched, max abs err {:.3e}",
                spec.config_id(&config),
                shape.tag(),
                report.mismatched,
                report.max_abs_err
            );
        }
    }
}

/// The ikj and jki orders accumulate in ascending-k order for every
/// element, so they are bit-identical to the naive reference — a
/// stronger-than-tolerance check that the tiling math is exact.
#[test]
fn ascending_k_orders_are_bitwise_exact() {
    let shape = GemmShape::new(33, 21, 19);
    let (a, b) = gemm::inputs(shape, 99);
    let want = gemm::reference(&a, &b, shape);
    for config in gemm::configs() {
        if config["loop_order"] == 0 && config["unroll"] != 1 {
            continue; // ijk re-associates under unroll; tolerance covers it
        }
        let got = gemm::run_config(&a, &b, shape, &config);
        assert_eq!(got, want, "config {:?}", gemm::space().config_id(&config));
    }
}
