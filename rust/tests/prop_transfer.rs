//! Property tests for the fingerprint-similarity transfer engine,
//! driven by the crate's deterministic RNG (no proptest in the pinned
//! set): the similarity metric is a well-behaved kernel (symmetric,
//! self-distance zero, bounded), and ranking puts near-identical
//! platforms ahead of disjoint-ISA ones regardless of recorded speedup.

use portatune::coordinator::perfdb::{DbEntry, Shard};
use portatune::coordinator::platform::Fingerprint;
use portatune::service::transfer::{rank_candidates, warm_start_configs};
use portatune::util::rng::Rng;

const ISA_POOL: &[&str] = &["sse2", "sse4_2", "avx", "avx2", "avx512f", "fma", "neon", "sve"];
const CACHE_POOL: &[u64] = &[0, 16, 32, 48, 64, 256, 512, 1024, 2048, 8192, 33792];

fn random_fingerprint(rng: &mut Rng) -> Fingerprint {
    let n_isa = rng.gen_range(ISA_POOL.len() + 1);
    let mut pool: Vec<&str> = ISA_POOL.to_vec();
    rng.shuffle(&mut pool);
    Fingerprint {
        cpu_model: format!("CPU-{}", rng.gen_range(1000)),
        num_cpus: 1 + rng.gen_range(128),
        simd: pool[..n_isa].iter().map(|s| s.to_string()).collect(),
        cache_l1d_kb: CACHE_POOL[rng.gen_range(CACHE_POOL.len())],
        cache_l2_kb: CACHE_POOL[rng.gen_range(CACHE_POOL.len())],
        cache_l3_kb: CACHE_POOL[rng.gen_range(CACHE_POOL.len())],
        os: if rng.gen_range(4) == 0 { "macos".into() } else { "linux".into() },
    }
}

fn entry(platform: &str, kernel: &str, tag: &str, id: &str, speedup: f64) -> DbEntry {
    DbEntry {
        platform_key: platform.into(),
        kernel: kernel.into(),
        tag: tag.into(),
        best_params: [("block_size".to_string(), 256i64)].into_iter().collect(),
        best_config_id: id.into(),
        best_time_s: 1e-3,
        baseline_time_s: 1e-3 * speedup,
        reference_time_s: 9e-4,
        evaluations: 4,
        strategy: "exhaustive".into(),
        recorded_at: 1_700_000_000,
    }
}

#[test]
fn prop_similarity_is_symmetric() {
    let mut rng = Rng::new(0x5144);
    for case in 0..500 {
        let a = random_fingerprint(&mut rng);
        let b = random_fingerprint(&mut rng);
        let ab = a.similarity(&b);
        let ba = b.similarity(&a);
        assert!(
            (ab - ba).abs() < 1e-12,
            "case {case}: similarity asymmetric: {ab} vs {ba}\n a={a:?}\n b={b:?}"
        );
    }
}

#[test]
fn prop_self_distance_is_exactly_zero() {
    let mut rng = Rng::new(0xD15);
    for case in 0..500 {
        let a = random_fingerprint(&mut rng);
        assert_eq!(a.similarity(&a), 1.0, "case {case}: self-similarity: {a:?}");
        assert_eq!(a.distance(&a), 0.0, "case {case}: self-distance: {a:?}");
    }
}

#[test]
fn prop_similarity_is_bounded() {
    let mut rng = Rng::new(0xB0);
    for case in 0..500 {
        let a = random_fingerprint(&mut rng);
        let b = random_fingerprint(&mut rng);
        let s = a.similarity(&b);
        assert!((0.0..=1.0).contains(&s), "case {case}: out of range: {s}");
    }
}

/// A near-identical platform's candidate must outrank a disjoint-ISA
/// platform's, whatever speedups were recorded on either.
#[test]
fn prop_near_identical_outranks_disjoint_isa() {
    let mut rng = Rng::new(0xAA);
    for case in 0..200 {
        let mut target = random_fingerprint(&mut rng);
        // Ensure the target has a non-empty ISA so "disjoint" is
        // meaningful (an empty-vs-empty comparison is a perfect match).
        if target.simd.is_empty() {
            target.simd = vec!["avx".into(), "avx2".into()];
        }
        target.os = "linux".into();

        // Near-identical: same machine, one cache level nudged.
        let mut near = target.clone();
        near.cache_l2_kb = near.cache_l2_kb.max(256) * 2;

        // Disjoint ISA, alien geometry, other OS.
        let far = Fingerprint {
            cpu_model: "Alien".into(),
            num_cpus: target.num_cpus * 4 + 1,
            simd: ISA_POOL
                .iter()
                .filter(|f| !target.simd.iter().any(|t| t == **f))
                .map(|f| f.to_string())
                .collect(),
            cache_l1d_kb: 7,
            cache_l2_kb: 0,
            cache_l3_kb: 999_999,
            os: "macos".into(),
        };

        let near_speedup = 1.0 + rng.next_f64();
        let far_speedup = near_speedup + 1.0 + 8.0 * rng.next_f64(); // always higher
        let shards = vec![
            Shard {
                platform_key: "far-box".into(),
                fingerprint: Some(far),
                entries: vec![entry("far-box", "axpy", "n4096", "far_cfg", far_speedup)],
                portfolios: Vec::new(),
                ledger: Default::default(),
            },
            Shard {
                platform_key: "near-box".into(),
                fingerprint: Some(near),
                entries: vec![entry("near-box", "axpy", "n4096", "near_cfg", near_speedup)],
                portfolios: Vec::new(),
                ledger: Default::default(),
            },
        ];
        let ranked = rank_candidates(&shards, &target, "axpy", "n4096", "local-key");
        assert!(!ranked.is_empty(), "case {case}: near platform must contribute");
        assert_eq!(
            ranked[0].entry.best_config_id, "near_cfg",
            "case {case}: disjoint-ISA platform outranked a near-identical one \
             (near sim {:.3}, target {target:?})",
            ranked[0].similarity
        );
    }
}

/// Ranking output invariants: similarity non-increasing, no duplicate
/// config ids, excluded platform absent, cap respected.
#[test]
fn prop_ranking_invariants() {
    let mut rng = Rng::new(0x1234);
    for case in 0..100 {
        let target = random_fingerprint(&mut rng);
        let n_shards = 1 + rng.gen_range(8);
        let mut shards = Vec::new();
        for s in 0..n_shards {
            let key = format!("box-{s}");
            let n_entries = 1 + rng.gen_range(4);
            let entries = (0..n_entries)
                .map(|_| {
                    entry(
                        &key,
                        "axpy",
                        if rng.gen_range(2) == 0 { "n4096" } else { "n65536" },
                        &format!("cfg_{}", rng.gen_range(6)),
                        1.0 + rng.next_f64(),
                    )
                })
                .collect();
            let fingerprint =
                if rng.gen_range(4) == 0 { None } else { Some(random_fingerprint(&mut rng)) };
            shards.push(Shard {
                platform_key: key,
                fingerprint,
                entries,
                portfolios: Vec::new(),
                ledger: Default::default(),
            });
        }
        let ranked = rank_candidates(&shards, &target, "axpy", "n4096", "box-0");
        for w in ranked.windows(2) {
            assert!(
                w[0].similarity >= w[1].similarity,
                "case {case}: ranking not sorted by similarity"
            );
        }
        let mut ids: Vec<&str> =
            ranked.iter().map(|c| c.entry.best_config_id.as_str()).collect();
        ids.sort_unstable();
        let before = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), before, "case {case}: duplicate config ids in ranking");
        assert!(
            ranked.iter().all(|c| c.platform_key != "box-0"),
            "case {case}: excluded platform leaked into ranking"
        );
        let capped = warm_start_configs(&ranked, 3);
        assert!(capped.len() <= 3);
    }
}
