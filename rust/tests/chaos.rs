//! Chaos harness: the daemon, a drainer fleet, and record clients under
//! a seeded fault schedule (`portatune::service::faults`).
//!
//! Every test asserts *end-state invariants* that must hold under any
//! schedule the budgeted spec can produce — no task lost or settled
//! twice, no acknowledged record lost, every client call eventually
//! answered, same seed ⇒ same schedule.  `CHAOS_SEED` (decimal u64)
//! overrides the seed; CI runs the fixed default plus one random seed
//! per build, and every run prints the seed so a failing schedule can
//! be replayed exactly.
//!
//! Budget analysis behind the spec below: a lease expiry charges an
//! attempt toward `MAX_ATTEMPTS` (3), and two faults can orphan a
//! lease — `worker.crash` (drainer abandons it) and `server.reply-drop`
//! on a task-lease reply (lease created, worker never learns).  Their
//! combined `max_hits` budget is 2, so no task can accumulate 3 charged
//! attempts and be dropped, under *any* seed.  `shard.torn-write` fails
//! before the rename, so a failed record attempt never commits and an
//! app-level re-record (fresh request id) cannot duplicate.
//!
//! The installed fault plan is process-global, so every serving test
//! holds `SERIAL` and clears the plan on exit (drop-safe on panic).

use std::io::Read;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use portatune::coordinator::perfdb::{unix_now, DbEntry, ShardedDb};
use portatune::coordinator::platform::Fingerprint;
use portatune::service::audit::{head_path, read_verified, verify_log, AuditEvent, AuditLog};
use portatune::service::faults::{self, FaultPlan, InjectionPoint};
use portatune::service::{Client, Request, RetryPolicy, ServeOpts, Server};
use portatune::util::json::Json;

/// The drain test's schedule.  Probabilities are moderate so different
/// seeds genuinely produce different schedules; budgets are small so
/// the system quiesces (and see the attempt-budget analysis above).
const DRAIN_SPEC: &str = "worker.crash:1.0:1,server.reply-drop:0.25:1,server.read-stall:0.25:3,\
                          client.connect-drop:0.25:2,client.read-stall:0.25:3,\
                          lease.settle-delay:0.25:3,shard.torn-write:1.0:2";

fn chaos_seed() -> u64 {
    match std::env::var("CHAOS_SEED") {
        Ok(s) => s.trim().parse().expect("CHAOS_SEED must be a decimal u64"),
        Err(_) => faults::DEFAULT_SEED,
    }
}

/// Serializes serving tests (the fault plan and the daemon's TCP port
/// churn are process-wide) and clears any installed plan on drop, so a
/// panicking test cannot leak its faults into the next one.
struct ChaosGuard {
    _serial: std::sync::MutexGuard<'static, ()>,
}

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

fn chaos_guard() -> ChaosGuard {
    static SERIAL: Mutex<()> = Mutex::new(());
    ChaosGuard { _serial: SERIAL.lock().unwrap_or_else(std::sync::PoisonError::into_inner) }
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("portatune-chaos-{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn fp() -> Fingerprint {
    Fingerprint {
        cpu_model: "Chaos CPU".into(),
        num_cpus: 8,
        simd: vec!["avx2".into(), "fma".into()],
        cache_l1d_kb: 32,
        cache_l2_kb: 1024,
        cache_l3_kb: 8192,
        os: "linux".into(),
    }
}

fn entry(platform: &str, kernel: &str, tag: &str, id: &str, recorded_at: u64) -> DbEntry {
    DbEntry {
        platform_key: platform.into(),
        kernel: kernel.into(),
        tag: tag.into(),
        best_params: [("block_size".to_string(), 512i64)].into_iter().collect(),
        best_config_id: id.into(),
        best_time_s: 1e-3,
        baseline_time_s: 2e-3,
        reference_time_s: 9e-4,
        evaluations: 8,
        strategy: "exhaustive".into(),
        recorded_at,
    }
}

/// Tight timeouts so a faulted call fails fast; four attempts out-last
/// every bounded fault budget in [`DRAIN_SPEC`].
fn chaos_policy() -> RetryPolicy {
    RetryPolicy {
        attempts: 4,
        base_delay: Duration::from_millis(10),
        max_delay: Duration::from_millis(100),
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_secs(2),
    }
}

fn start_server(
    dir: &std::path::Path,
    opts: ServeOpts,
) -> (Arc<Server>, String, std::thread::JoinHandle<()>) {
    start_server_audited(dir, opts, None)
}

fn start_server_audited(
    dir: &std::path::Path,
    opts: ServeOpts,
    audit: Option<&std::path::Path>,
) -> (Arc<Server>, String, std::thread::JoinHandle<()>) {
    let db = ShardedDb::open(dir).unwrap();
    let server = Arc::new(Server::new(db, fp(), opts));
    if let Some(path) = audit {
        server.enable_audit(Arc::new(AuditLog::open(path).unwrap()));
    }
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = Arc::clone(&server);
    let handle = std::thread::spawn(move || srv.run_tcp(listener).unwrap());
    (server, addr, handle)
}

fn lookup(platform: &str, kernel: &str, workload: &str) -> Request {
    Request::Lookup {
        platform: Some(platform.to_string()),
        kernel: kernel.to_string(),
        workload: workload.to_string(),
    }
}

fn stat(client: &Client, field: &str) -> u64 {
    let reply = client.call(&Request::Stats).unwrap();
    reply.get("stats").and_then(|s| s.get(field)).and_then(Json::as_u64).unwrap()
}

/// The headline chaos run: a daemon with 10 queued re-tune tasks, two
/// drainer threads, and two record threads, all under [`DRAIN_SPEC`].
/// End state, regardless of seed: every task settles exactly once
/// (crashed leases recover via expiry, lost acks dedupe via request
/// id), and every acknowledged record is served back.
#[test]
fn faulted_drain_loses_no_tasks_and_no_records() {
    let _guard = chaos_guard();
    let seed = chaos_seed();
    eprintln!("chaos drain seed: {seed} ({seed:#x})");

    let dir = tmp_dir("drain");
    let db = ShardedDb::open(&dir).unwrap();
    for i in 0..5 {
        db.record(None, entry("box-a", "axpy", &format!("n{i}"), "stale", 1000)).unwrap();
        db.record(None, entry("box-b", "dot", &format!("n{i}"), "stale", 1000)).unwrap();
    }
    let audit_path = dir.join("audit.log");
    let (server, addr, serve_thread) =
        start_server_audited(&dir, ServeOpts::default(), Some(&audit_path));
    assert_eq!(server.scan_once().unwrap(), 10, "10 stale frontier entries queue 10 re-tunes");

    faults::install(FaultPlan::from_spec(DRAIN_SPEC, seed).unwrap());

    // Drainers: lease → (maybe crash) → complete, until all 10 settle.
    // A crash abandons the lease; only its 2 s TTL recovers the task.
    let completed = Arc::new(AtomicU64::new(0));
    let identities = Arc::new(Mutex::new(Vec::<String>::new()));
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut drainers = Vec::new();
    for _ in 0..2 {
        let addr = addr.clone();
        let completed = Arc::clone(&completed);
        let identities = Arc::clone(&identities);
        drainers.push(std::thread::spawn(move || {
            let client = Client::tcp(addr).with_policy(chaos_policy());
            while completed.load(Ordering::SeqCst) < 10 && Instant::now() < deadline {
                let leased = match client.lease_task(None, None, Some(2)) {
                    Ok(Some(leased)) => leased,
                    _ => {
                        std::thread::sleep(Duration::from_millis(100));
                        continue;
                    }
                };
                if faults::hit(InjectionPoint::WorkerCrash) {
                    continue; // crash before settling; expiry requeues
                }
                match client.complete_task(leased.lease_id) {
                    Ok(true) => {
                        let task = &leased.task;
                        let id =
                            format!("{}/{}/{:?}", task.platform_key, task.kernel, task.tag);
                        identities.lock().unwrap().push(id);
                        completed.fetch_add(1, Ordering::SeqCst);
                    }
                    Ok(false) => {} // someone else settled it; not ours
                    Err(_) => {}    // ack lost beyond retries; expiry requeues
                }
            }
        }));
    }

    // Recorders: 10 unique entries each.  A torn-write fault surfaces
    // as a definitive daemon error with nothing committed, so the
    // app-level retry (fresh request id per attempt) is dedupe-safe.
    let mut recorders = Vec::new();
    for t in 0..2u64 {
        let addr = addr.clone();
        recorders.push(std::thread::spawn(move || {
            let client = Client::tcp(addr).with_policy(chaos_policy());
            for i in 0..10 {
                let e = entry(
                    "rec-box",
                    "axpy",
                    &format!("t{t}n{i}"),
                    &format!("cfg{t}_{i}"),
                    unix_now(),
                );
                let committed = (0..10).any(|_| {
                    if client.record(e.clone(), None).is_ok() {
                        return true;
                    }
                    std::thread::sleep(Duration::from_millis(50));
                    false
                });
                assert!(committed, "record t{t}n{i} never succeeded");
            }
        }));
    }
    for h in recorders {
        h.join().unwrap();
    }
    for h in drainers {
        h.join().unwrap();
    }

    // Verification runs fault-free: the faulted phase is over.
    faults::clear();
    assert_eq!(completed.load(Ordering::SeqCst), 10, "every task must settle exactly once");
    let mut ids = identities.lock().unwrap().clone();
    ids.sort();
    let distinct = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), distinct, "a task settled twice: {ids:?}");
    assert_eq!(distinct, 10);

    let client = Client::tcp(addr);
    assert_eq!(stat(&client, "tasks_completed"), 10, "daemon ledger disagrees with drainers");
    for t in 0..2u64 {
        for i in 0..10 {
            let reply = client.call(&lookup("rec-box", "axpy", &format!("t{t}n{i}"))).unwrap();
            assert_eq!(
                reply.get("found").and_then(Json::as_bool),
                Some(true),
                "acknowledged record t{t}n{i} was lost"
            );
        }
    }
    let _ = client.call(&Request::Shutdown);
    serve_thread.join().unwrap();

    // The audit log written under the fault schedule must verify
    // intact, and its ledger must agree with the drainers: exactly 10
    // task-completed entries, one per settled task.
    let report = verify_log(&audit_path).expect("faulted run must leave a verifiable audit log");
    assert!(report.entries >= 20, "expected enqueues + leases + settlements, got {report:?}");
    let entries = read_verified(&audit_path).unwrap();
    let settled = entries
        .iter()
        .filter(|e| matches!(e.event, AuditEvent::TaskCompleted { .. }))
        .count();
    assert_eq!(settled, 10, "audit ledger disagrees with the drainers");

    // Tamper evidence: flip one byte inside a mid-log entry (on a copy)
    // and verification must fail naming exactly that entry.
    let tampered = dir.join("tampered.log");
    let mut bytes = std::fs::read(&audit_path).unwrap();
    let line_starts: Vec<usize> = std::iter::once(0)
        .chain(bytes.iter().enumerate().filter(|(_, b)| **b == b'\n').map(|(i, _)| i + 1))
        .collect();
    let victim = entries.len() / 2;
    bytes[line_starts[victim] + 4] ^= 0x01;
    std::fs::write(&tampered, &bytes).unwrap();
    std::fs::copy(head_path(&audit_path), head_path(&tampered)).unwrap();
    let err = verify_log(&tampered).expect_err("a flipped byte must fail verification");
    assert_eq!(err.index(), Some(victim as u64), "tamper must name the flipped entry: {err}");

    // Truncation: drop the tail entries but keep the head sidecar —
    // verification must fail naming the first missing entry.
    let keep = entries.len() - 2;
    let truncated_bytes = std::fs::read(&audit_path).unwrap();
    std::fs::write(&tampered, &truncated_bytes[..line_starts[keep]]).unwrap();
    let err = verify_log(&tampered).expect_err("a truncated tail must fail verification");
    assert_eq!(err.index(), Some(keep as u64), "truncation must name the first lost entry: {err}");

    std::fs::remove_dir_all(&dir).ok();
}

/// The replayability contract: one seed, one schedule — across every
/// injection point, under the drain spec itself.
#[test]
fn same_seed_replays_the_same_schedule() {
    let seed = chaos_seed();
    eprintln!("chaos schedule seed: {seed} ({seed:#x})");
    let a = FaultPlan::from_spec(DRAIN_SPEC, seed).unwrap();
    let b = FaultPlan::from_spec(DRAIN_SPEC, seed).unwrap();
    for n in 0..500 {
        for p in faults::ALL_POINTS {
            assert_eq!(
                a.decide(p),
                b.decide(p),
                "schedules diverged at occurrence {n} of {}",
                p.as_str()
            );
        }
    }
    // And a different seed is a different schedule (unbounded point, so
    // budget exhaustion cannot mask the divergence).
    let c = FaultPlan::from_spec("server.reply-drop:0.5", seed).unwrap();
    let d = FaultPlan::from_spec("server.reply-drop:0.5", seed ^ 0x9e37_79b9).unwrap();
    let agreed = (0..512)
        .filter(|_| {
            c.decide(InjectionPoint::ServerReplyDrop) == d.decide(InjectionPoint::ServerReplyDrop)
        })
        .count();
    assert!(agreed < 512, "different seeds produced identical schedules");
}

/// Shard corruption behind a live daemon: the poisoned shard degrades
/// to a lookup miss and a `.corrupt` quarantine — never an error or a
/// panic — and the next record rebuilds a servable shard.
#[test]
fn corrupt_shard_quarantines_and_recovers_over_the_wire() {
    let _guard = chaos_guard();
    let dir = tmp_dir("corrupt");
    let (_server, addr, serve_thread) = start_server(&dir, ServeOpts::default());
    let client = Client::tcp(addr);
    client.record(entry("corrupt-box", "axpy", "n4096", "good", unix_now()), None).unwrap();

    // Corrupt the shard on disk behind the daemon's back.  The shard
    // path hashing is a store implementation detail, so find the file
    // by suffix.
    let shard_file = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(".shard.json"))
        })
        .expect("the record must have published a shard file");
    std::fs::write(&shard_file, "{\"schema\": 2, \"entries\": [{\"platform_k").unwrap();

    let reply = client.call(&lookup("corrupt-box", "axpy", "n4096")).unwrap();
    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(false));
    // Quarantine corpses are timestamped (`<shard>.corrupt.<ts>`), so
    // count by marker rather than guessing the exact name.
    let corpses = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.contains(".corrupt."))
        })
        .count();
    assert_eq!(corpses, 1, "torn shard must be quarantined, not deleted");
    assert!(!shard_file.exists(), "torn shard must be moved aside");

    client.record(entry("corrupt-box", "axpy", "n4096", "fresh", unix_now()), None).unwrap();
    let reply = client.call(&lookup("corrupt-box", "axpy", "n4096")).unwrap();
    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
    assert_eq!(
        reply.get("entry").and_then(|e| e.get("best_config_id")).and_then(Json::as_str),
        Some("fresh")
    );

    let _ = client.call(&Request::Shutdown);
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Past `max_conns` in-flight connections the daemon sheds instead of
/// queueing: one `overloaded` reply (transient to the client's retry
/// classifier), then the socket closes; capacity frees as holders
/// disconnect and the shed shows up in the stats.
#[test]
fn connection_cap_sheds_with_a_retryable_overloaded_reply() {
    let _guard = chaos_guard();
    let dir = tmp_dir("cap");
    let opts = ServeOpts { max_conns: 2, ..ServeOpts::default() };
    let (_server, addr, serve_thread) = start_server(&dir, opts);

    let hold_a = std::net::TcpStream::connect(&addr).unwrap();
    let hold_b = std::net::TcpStream::connect(&addr).unwrap();
    std::thread::sleep(Duration::from_millis(300)); // let both get accepted

    let one_shot =
        Client::tcp(addr.clone()).with_policy(RetryPolicy { attempts: 1, ..chaos_policy() });
    let err = one_shot.call(&Request::Ping).unwrap_err();
    assert!(format!("{err:#}").contains("overloaded"), "want a shed reply, got: {err:#}");

    drop(hold_a);
    drop(hold_b);
    std::thread::sleep(Duration::from_millis(300)); // let the handlers drain
    let client = Client::tcp(addr);
    assert_eq!(
        client.call(&Request::Ping).unwrap().get("ok").and_then(Json::as_bool),
        Some(true),
        "capacity must free once holders disconnect"
    );
    assert!(stat(&client, "conns_shed") >= 1);

    let _ = client.call(&Request::Shutdown);
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// A connection that never sends a request is closed at the idle
/// deadline (a stalled peer cannot pin a connection slot forever).
#[test]
fn idle_connections_are_reaped_at_the_deadline() {
    let _guard = chaos_guard();
    let dir = tmp_dir("idle");
    let opts = ServeOpts { conn_idle_s: 1, ..ServeOpts::default() };
    let (_server, addr, serve_thread) = start_server(&dir, opts);

    let mut idle = std::net::TcpStream::connect(&addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let started = Instant::now();
    let mut buf = [0u8; 16];
    let n = idle.read(&mut buf).unwrap();
    assert_eq!(n, 0, "daemon must close the idle connection cleanly (EOF)");
    assert!(
        started.elapsed() >= Duration::from_millis(900),
        "closed before the idle deadline: {:?}",
        started.elapsed()
    );

    let client = Client::tcp(addr);
    assert!(stat(&client, "conns_closed_idle") >= 1);
    let _ = client.call(&Request::Shutdown);
    serve_thread.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
