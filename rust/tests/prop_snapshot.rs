//! Concurrency properties of the snapshot serve path.
//!
//! The serve refactor's contract: daemon state is an immutable
//! `ServeSnapshot` behind an atomically swapped `Arc`, writers
//! clone-merge-publish a new generation, and every read answers from
//! exactly one published snapshot.  These tests drive concurrent
//! recorders against a lookup storm and check the three properties the
//! design promises:
//!
//! 1. **Never torn** — every read observes a *complete* published
//!    snapshot: found entries carry all their invariant fields, and a
//!    platform recorded before the storm never transiently vanishes
//!    while unrelated platforms publish.
//! 2. **Monotone generations** — the `gen` echoed in every reply never
//!    decreases from any single observer's point of view.
//! 3. **Read-your-writes** — a read issued after an acked `record`
//!    (ack carries the publish's generation) sees that write: the
//!    served entry is at least as new as the acked one.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use portatune::coordinator::perfdb::{DbEntry, ShardedDb};
use portatune::coordinator::platform::Fingerprint;
use portatune::service::{Request, ServeOpts, Server};
use portatune::util::json::Json;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("portatune-propsnap-{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn fp() -> Fingerprint {
    Fingerprint {
        cpu_model: "Prop CPU".into(),
        num_cpus: 8,
        simd: vec!["avx2".into()],
        cache_l1d_kb: 32,
        cache_l2_kb: 1024,
        cache_l3_kb: 8192,
        os: "linux".into(),
    }
}

fn entry(platform: &str, id: &str, recorded_at: u64) -> DbEntry {
    DbEntry {
        platform_key: platform.into(),
        kernel: "axpy".into(),
        tag: "n4096".into(),
        best_params: [("block_size".to_string(), 512i64)].into_iter().collect(),
        best_config_id: id.into(),
        best_time_s: 1e-3,
        baseline_time_s: 2e-3,
        reference_time_s: 9e-4,
        evaluations: 8,
        strategy: "exhaustive".into(),
        recorded_at,
    }
}

fn lookup(platform: &str) -> Request {
    Request::Lookup {
        platform: Some(platform.into()),
        kernel: "axpy".into(),
        workload: "n4096".into(),
    }
}

/// A served entry must be exactly the shape some recorder published —
/// all invariant fields intact.  Anything else means a reader saw a
/// half-merged snapshot.
fn assert_complete_entry(reply: &Json) {
    let entry = reply.get("entry").expect("found reply must carry the entry");
    let id = entry.get("best_config_id").and_then(Json::as_str).unwrap_or("");
    assert!(
        id == "seed_cfg" || id.starts_with("cfg_t"),
        "config id from an unknown write: {id:?}"
    );
    assert_eq!(
        entry.get("best_params").and_then(|p| p.get("block_size")).and_then(Json::as_i64),
        Some(512),
        "params must round-trip whole"
    );
    assert_eq!(entry.get("evaluations").and_then(Json::as_u64), Some(8));
    assert_eq!(entry.get("strategy").and_then(Json::as_str), Some("exhaustive"));
    assert!(entry.get("recorded_at").and_then(Json::as_u64).unwrap_or(0) > 0);
}

/// Concurrent recorders + a lookup storm: never-torn reads, monotone
/// generations, and the pre-recorded stable platform stays visible
/// through every clone-merge-publish of the contended one.
#[test]
fn lookup_storm_over_concurrent_recorders_sees_only_published_snapshots() {
    const RECORDERS: usize = 3;
    const PER_RECORDER: usize = 8;
    const READERS: usize = 3;

    let dir = tmp_dir("storm");
    let db = ShardedDb::open(&dir).unwrap();
    // A platform recorded before the storm; publishes for prop-box must
    // never make it flicker out of the snapshot.
    db.record(None, entry("stable-box", "seed_cfg", 1_700_000_000)).unwrap();
    let server = Arc::new(Server::new(db, fp(), ServeOpts::default()));
    assert_eq!(server.stats().snapshot_gen, 0, "initial snapshot is generation 0");

    let stop = Arc::new(AtomicBool::new(false));
    let clock = Arc::new(AtomicU64::new(1_700_000_001));

    let mut readers = Vec::new();
    for _ in 0..READERS {
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        readers.push(std::thread::spawn(move || {
            let mut last_gen = 0u64;
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // The contended platform: found or not, the reply must
                // come whole from one snapshot.
                let reply = server.handle_request(&lookup("prop-box"));
                let gen = reply
                    .get("gen")
                    .and_then(Json::as_u64)
                    .expect("every lookup reply echoes its snapshot generation");
                assert!(
                    gen >= last_gen,
                    "generation went backwards: {gen} after {last_gen}"
                );
                last_gen = gen;
                if reply.get("found").and_then(Json::as_bool) == Some(true) {
                    assert_complete_entry(&reply);
                }
                // The stable platform: always present, in full.
                let reply = server.handle_request(&lookup("stable-box"));
                assert_eq!(
                    reply.get("found").and_then(Json::as_bool),
                    Some(true),
                    "a platform in the snapshot must never transiently vanish"
                );
                assert_complete_entry(&reply);
                reads += 1;
            }
            reads
        }));
    }

    let mut recorders = Vec::new();
    for t in 0..RECORDERS {
        let server = Arc::clone(&server);
        let clock = Arc::clone(&clock);
        recorders.push(std::thread::spawn(move || {
            let mut last_ack_gen = 0u64;
            for i in 0..PER_RECORDER {
                let ts = clock.fetch_add(1, Ordering::Relaxed);
                let reply = server.handle_request(&Request::Record {
                    entry: Box::new(entry("prop-box", &format!("cfg_t{t}_i{i}"), ts)),
                    fingerprint: None,
                    request_id: None,
                    spend_ms: None,
                });
                assert_eq!(reply.get("recorded").and_then(Json::as_bool), Some(true));
                let ack_gen = reply
                    .get("gen")
                    .and_then(Json::as_u64)
                    .expect("a record ack echoes the generation it published");
                assert!(
                    ack_gen > last_ack_gen,
                    "each record publishes a strictly newer generation \
                     ({ack_gen} after {last_ack_gen})"
                );
                last_ack_gen = ack_gen;

                // Read-your-writes: a read issued after the ack must
                // observe a snapshot at least as new as the ack's
                // generation, containing a write at least as new as
                // ours (another recorder's newer entry also counts).
                let reply = server.handle_request(&lookup("prop-box"));
                let read_gen = reply.get("gen").and_then(Json::as_u64).unwrap();
                assert!(
                    read_gen >= ack_gen,
                    "read after ack ran against an older snapshot: \
                     read gen {read_gen} < acked gen {ack_gen}"
                );
                assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
                let seen_ts = reply
                    .get("entry")
                    .and_then(|e| e.get("recorded_at"))
                    .and_then(Json::as_u64)
                    .unwrap();
                assert!(
                    seen_ts >= ts,
                    "read after ack served an entry older than the acked write \
                     ({seen_ts} < {ts})"
                );
            }
        }));
    }

    for r in recorders {
        r.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let mut total_reads = 0;
    for r in readers {
        total_reads += r.join().unwrap();
    }
    assert!(total_reads > 0, "the storm must actually have read something");

    // Quiesced: exactly one publish per record happened, the final
    // snapshot serves the newest write, and the stable shard survived
    // every merge.
    let stats = server.stats();
    assert_eq!(stats.snapshot_gen, (RECORDERS * PER_RECORDER) as u64);
    assert_eq!(stats.snapshot_publishes, (RECORDERS * PER_RECORDER) as u64);
    let final_ts = clock.load(Ordering::Relaxed) - 1;
    let reply = server.handle_request(&lookup("prop-box"));
    assert_eq!(
        reply.get("entry").and_then(|e| e.get("recorded_at")).and_then(Json::as_u64),
        Some(final_ts),
        "the frontier must converge on the newest recorded entry"
    );
    let reply = server.handle_request(&lookup("stable-box"));
    assert_eq!(
        reply
            .get("entry")
            .and_then(|e| e.get("best_config_id"))
            .and_then(Json::as_str),
        Some("seed_cfg")
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The generation echo, single-observer edition: acks and reads agree
/// on ordering even with no concurrency, and a refresh republishes at
/// a strictly newer generation without changing answers.
#[test]
fn generation_echo_orders_acks_and_reads() {
    let dir = tmp_dir("gen-echo");
    let db = ShardedDb::open(&dir).unwrap();
    let server = Server::new(db, fp(), ServeOpts::default());

    let miss = server.handle_request(&lookup("prop-box"));
    assert_eq!(miss.get("found").and_then(Json::as_bool), Some(false));
    assert_eq!(miss.get("gen").and_then(Json::as_u64), Some(0));

    let ack1 = server.handle_request(&Request::Record {
        entry: Box::new(entry("prop-box", "cfg_t0_i0", 1_700_000_010)),
        fingerprint: None,
        request_id: None,
        spend_ms: None,
    });
    let g1 = ack1.get("gen").and_then(Json::as_u64).unwrap();
    assert_eq!(g1, 1);

    let read1 = server.handle_request(&lookup("prop-box"));
    assert!(read1.get("gen").and_then(Json::as_u64).unwrap() >= g1);
    assert_eq!(
        read1.get("entry").and_then(|e| e.get("best_config_id")).and_then(Json::as_str),
        Some("cfg_t0_i0")
    );

    let ack2 = server.handle_request(&Request::Record {
        entry: Box::new(entry("prop-box", "cfg_t0_i1", 1_700_000_020)),
        fingerprint: None,
        request_id: None,
        spend_ms: None,
    });
    let g2 = ack2.get("gen").and_then(Json::as_u64).unwrap();
    assert!(g2 > g1);

    let read2 = server.handle_request(&lookup("prop-box"));
    assert!(read2.get("gen").and_then(Json::as_u64).unwrap() >= g2);
    assert_eq!(
        read2.get("entry").and_then(|e| e.get("best_config_id")).and_then(Json::as_str),
        Some("cfg_t0_i1"),
        "read after the second ack must see the second write"
    );

    // An explicit refresh republishes from disk at a newer generation;
    // the answer is unchanged.
    let g3 = server.refresh_snapshot().unwrap();
    assert!(g3 > g2);
    let read3 = server.handle_request(&lookup("prop-box"));
    assert_eq!(read3.get("gen").and_then(Json::as_u64), Some(g3));
    assert_eq!(
        read3.get("entry").and_then(|e| e.get("best_config_id")).and_then(Json::as_str),
        Some("cfg_t0_i1")
    );
    std::fs::remove_dir_all(&dir).ok();
}
