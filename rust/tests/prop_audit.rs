//! Exhaustive adversarial properties of the audit chain.
//!
//! The unit tests in `service/audit/` check one tampering example each;
//! these tests check the *space*: every byte of a log flipped one at a
//! time, truncation at (and inside) every entry boundary, byte-level
//! replay determinism, and chain integrity under concurrent appenders.
//! The invariant throughout: verification never passes on altered
//! evidence, and every failure names the exact entry it pinned down.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use portatune::service::audit::{
    head_path, read_verified, verify_log, AuditEvent, AuditLog, ServeReason,
};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "portatune-propaudit-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A log touching every event variant and both serve-reason shapes, so
/// the flip sweep exercises every encoder path.
fn build_log(path: &Path) -> Vec<u8> {
    let log = AuditLog::open(path).unwrap();
    let events = vec![
        AuditEvent::TaskEnqueued {
            kind: "retune".into(),
            platform: "alpha".into(),
            kernel: "axpy".into(),
            tag: Some("n4096".into()),
            reason: "ttl-expired".into(),
        },
        AuditEvent::TaskLeased {
            lease_id: 1,
            kind: "retune".into(),
            platform: "alpha".into(),
            kernel: "axpy".into(),
        },
        AuditEvent::TaskCompleted { lease_id: 1 },
        AuditEvent::TaskFailed { lease_id: 2, error: "measurement failed".into() },
        AuditEvent::TaskRequeued {
            kind: "sweep".into(),
            platform: "beta".into(),
            kernel: "gemm".into(),
            attempts: 1,
        },
        AuditEvent::TaskDropped {
            kind: "sweep".into(),
            platform: "beta".into(),
            kernel: "gemm".into(),
            attempts: 3,
        },
        AuditEvent::RecordAccepted {
            platform: "alpha".into(),
            kernel: "axpy".into(),
            tag: "n4096".into(),
            config: "b256_u4".into(),
        },
        AuditEvent::Served {
            op: "deploy".into(),
            platform: "gamma".into(),
            kernel: "axpy".into(),
            workload: Some("n4096".into()),
            reason: ServeReason::Transfer { source: "alpha".into(), similarity_pm: 875 },
            trace_id: Some("tcafe-99-3".into()),
        },
        AuditEvent::Served {
            op: "lookup".into(),
            platform: "alpha".into(),
            kernel: "axpy".into(),
            workload: Some("n4096".into()),
            reason: ServeReason::Exact,
            trace_id: None,
        },
        AuditEvent::Served {
            op: "portfolio".into(),
            platform: "delta".into(),
            kernel: "gemm".into(),
            workload: None,
            reason: ServeReason::Miss,
            trace_id: None,
        },
    ];
    for (i, ev) in events.into_iter().enumerate() {
        log.append_at(1000 + i as u64, ev).unwrap();
    }
    std::fs::read(path).unwrap()
}

/// Write `bytes` as a tampered copy next to `original`, bringing the
/// head sidecar along so truncation detection stays armed.
fn tampered_copy(original: &Path, bytes: &[u8], name: &str) -> PathBuf {
    let copy = original.with_file_name(name);
    std::fs::write(&copy, bytes).unwrap();
    std::fs::copy(head_path(original), head_path(&copy)).unwrap();
    copy
}

#[test]
fn every_flipped_byte_is_pinned_to_its_entry() {
    let dir = tmp_dir("flip");
    let path = dir.join("audit.log");
    let bytes = build_log(&path);
    assert!(verify_log(&path).is_ok(), "pristine log must verify");

    for p in 0..bytes.len() {
        // The entry owning byte `p` is the number of full lines before
        // it.  Flipping the final newline tears the last entry off,
        // which the head commitment reports as truncation — at the
        // same index.
        let owner = bytes[..p].iter().filter(|&&b| b == b'\n').count() as u64;
        let mut flipped = bytes.clone();
        flipped[p] ^= 0x01;
        let copy = tampered_copy(&path, &flipped, "flipped.log");
        let err = verify_log(&copy)
            .expect_err(&format!("flip of byte {p} (entry {owner}) went undetected"));
        assert_eq!(
            err.index(),
            Some(owner),
            "flip of byte {p} pinned the wrong entry: {err}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncation_at_and_inside_every_boundary_is_pinned() {
    let dir = tmp_dir("trunc");
    let path = dir.join("audit.log");
    let bytes = build_log(&path);
    let mut line_starts = vec![0usize];
    line_starts.extend(
        bytes.iter().enumerate().filter(|(_, &b)| b == b'\n').map(|(i, _)| i + 1),
    );
    let n = line_starts.len() - 1; // final element is EOF

    for k in 0..n {
        // Cut exactly at the boundary: k complete entries survive.
        let copy = tampered_copy(&path, &bytes[..line_starts[k]], "cut.log");
        let err = verify_log(&copy).expect_err("truncated log verified");
        assert_eq!(err.index(), Some(k as u64), "boundary cut after {k} entries: {err}");

        // Cut mid-line: the torn half-entry is discarded, leaving the
        // same k complete entries — and the same pinned index.
        let mid = line_starts[k] + (line_starts[k + 1] - line_starts[k]) / 2;
        let copy = tampered_copy(&path, &bytes[..mid], "cut.log");
        let err = verify_log(&copy).expect_err("mid-line truncated log verified");
        assert_eq!(err.index(), Some(k as u64), "mid-line cut inside entry {k}: {err}");
    }

    // The full log, by contrast, is intact.
    let copy = tampered_copy(&path, &bytes, "cut.log");
    assert_eq!(verify_log(&copy).unwrap().entries, n as u64);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn identical_event_sequences_replay_to_identical_bytes() {
    let dir = tmp_dir("replay");
    let a = dir.join("a.log");
    let b = dir.join("b.log");
    let bytes_a = build_log(&a);
    let bytes_b = build_log(&b);
    assert_eq!(bytes_a, bytes_b, "same events + same timestamps must be byte-identical");
    assert_eq!(
        std::fs::read(head_path(&a)).unwrap(),
        std::fs::read(head_path(&b)).unwrap(),
        "head sidecars must agree too"
    );
    // And the replay input parses back to the same decisions.
    let ea = read_verified(&a).unwrap();
    let eb = read_verified(&b).unwrap();
    assert_eq!(ea.len(), eb.len());
    for (x, y) in ea.iter().zip(&eb) {
        assert_eq!(x.event, y.event);
        assert_eq!(x.hash, y.hash);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_appenders_keep_one_intact_chain() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 50;
    let dir = tmp_dir("concurrent");
    let path = dir.join("audit.log");
    let log = Arc::new(AuditLog::open(&path).unwrap());

    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let log = Arc::clone(&log);
            scope.spawn(move || {
                for i in 0..PER_THREAD {
                    log.append(AuditEvent::TaskCompleted { lease_id: t * PER_THREAD + i })
                        .unwrap();
                }
            });
        }
    });

    assert_eq!(log.appended(), THREADS * PER_THREAD);
    let report = verify_log(&path).unwrap();
    assert_eq!(report.entries, THREADS * PER_THREAD);
    assert!(report.head_present);
    assert_eq!(report.head_lag, 0);

    // Every appender's every entry made it in exactly once, in some
    // interleaving — seq numbering is dense by construction, and the
    // lease ids cover the full cross product.
    let entries = read_verified(&path).unwrap();
    let mut seen: Vec<u64> = entries
        .iter()
        .map(|e| match e.event {
            AuditEvent::TaskCompleted { lease_id } => lease_id,
            ref other => panic!("unexpected event in concurrent log: {other:?}"),
        })
        .collect();
    seen.sort_unstable();
    let expected: Vec<u64> = (0..THREADS * PER_THREAD).collect();
    assert_eq!(seen, expected);
    let _ = std::fs::remove_dir_all(&dir);
}
