//! Persistence integration: tune → record → save → reopen → deploy, and
//! the cross-platform warm-start transfer path.

use std::sync::Arc;

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::perfdb::{unix_now, DbEntry, PerfDb};
use portatune::coordinator::platform::Fingerprint;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::runtime::{Registry, Runtime};

fn registry() -> Option<Arc<Registry>> {
    // Build-time gate: without the real XLA backend (or without AOT
    // artifacts on disk) these integration tests skip rather than fail —
    // the hermetic unit/property suites still cover the coordinator.
    let runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return None;
        }
    };
    match Registry::open(runtime, "artifacts") {
        Ok(r) => Some(Arc::new(r)),
        Err(e) => {
            eprintln!("skipping: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn tmp_db(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("portatune-it-{}-{name}.json", std::process::id()))
}

#[test]
fn tune_record_save_reopen_deploy() {
    let Some(reg) = registry() else { return };
    let tuner = Tuner::new(&reg).with_measure_cfg(MeasureConfig::quick());
    let mut strategy = Exhaustive::new();
    let outcome = tuner.tune("axpy", "n4096", &mut strategy, usize::MAX).unwrap();

    let path = tmp_db("roundtrip");
    let mut db = PerfDb::open(&path).unwrap();
    tuner.record(&mut db, &outcome);
    db.save().unwrap();

    // Reopen from disk and verify the record survived.
    let db2 = PerfDb::open(&path).unwrap();
    let key = Fingerprint::detect().key();
    let entry = db2.lookup(&key, "axpy", "n4096").expect("recorded entry");
    assert_eq!(entry.best_config_id, outcome.best.as_ref().unwrap().config_id);
    assert!(entry.best_time_s > 0.0);
    assert!(entry.baseline_time_s > 0.0);
    assert!(entry.reference_time_s > 0.0);
    assert!(entry.speedup() >= 1.0 - 1e-9);

    // Deploy path resolves to the tuned variant's artifact.
    let deployed = tuner.deployed_artifact(&db2, "axpy", "n4096").unwrap();
    let (_, wl) = reg.find("axpy", "n4096").unwrap();
    let expected = &wl.variant(&entry.best_config_id).unwrap().path;
    assert_eq!(&deployed, expected);

    std::fs::remove_file(&path).ok();
}

#[test]
fn deploy_falls_back_to_reference_without_record() {
    let Some(reg) = registry() else { return };
    let tuner = Tuner::new(&reg);
    let db = PerfDb::open(tmp_db("empty")).unwrap();
    let deployed = tuner.deployed_artifact(&db, "axpy", "n65536").unwrap();
    let (_, wl) = reg.find("axpy", "n65536").unwrap();
    assert_eq!(deployed, wl.baseline);
}

#[test]
fn warm_start_transfers_config_across_platforms() {
    // Simulate a record from a *different* platform, then warm-start a
    // local tune from it with budget 0: the transferred config must be
    // evaluated and (being the true optimum recorded elsewhere) usable.
    let Some(reg) = registry() else { return };
    let tuner = Tuner::new(&reg).with_measure_cfg(MeasureConfig::quick());

    // First find the local optimum exhaustively (ground truth).
    let mut ex = Exhaustive::new();
    let truth = tuner.tune("axpy", "n4096", &mut ex, usize::MAX).unwrap();
    let best_cfg = truth.best.as_ref().unwrap().config.clone();
    let best_id = truth.best.as_ref().unwrap().config_id.clone();

    let mut db = PerfDb::open(tmp_db("xfer")).unwrap();
    db.record(DbEntry {
        platform_key: "other-machine-0123456789abcdef".into(),
        kernel: "axpy".into(),
        tag: "n4096".into(),
        best_params: best_cfg.clone(),
        best_config_id: best_id.clone(),
        best_time_s: 1e-3,
        baseline_time_s: 2e-3,
        reference_time_s: 9e-4,
        evaluations: 9,
        strategy: "exhaustive".into(),
        recorded_at: unix_now(),
    });

    let local_key = Fingerprint::detect().key();
    let candidates = db.warm_start("axpy", "n4096", &local_key);
    assert_eq!(candidates.len(), 1);
    assert_eq!(candidates[0], best_cfg);

    let warm_tuner = Tuner::new(&reg)
        .with_measure_cfg(MeasureConfig::quick())
        .with_warm_start(candidates);
    let mut ex2 = Exhaustive::new();
    // Budget 0: only default + warm-start evaluations run.
    let outcome = warm_tuner.tune("axpy", "n4096", &mut ex2, 0).unwrap();
    assert!(outcome.evaluations() <= 2);
    assert!(outcome
        .evaluated
        .iter()
        .any(|v| v.config_id == best_id), "warm-start config was not evaluated");
}

#[test]
fn corrupt_db_is_rejected_not_swallowed() {
    let path = tmp_db("corrupt");
    std::fs::write(&path, "{definitely not json").unwrap();
    assert!(PerfDb::open(&path).is_err());
    std::fs::remove_file(&path).ok();
}
