//! End-to-end tuner integration: the full paper pipeline over real
//! artifacts, plus annotation-driven spec construction.

use std::sync::Arc;

use portatune::coordinator::annotation::{extract_blocks, Annotation};
use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::{Anneal, Exhaustive, HillClimb, RandomSearch};
use portatune::coordinator::spec::TuningSpec;
use portatune::coordinator::tuner::Tuner;
use portatune::runtime::{Registry, Runtime};

fn registry() -> Option<Arc<Registry>> {
    // Build-time gate: without the real XLA backend (or without AOT
    // artifacts on disk) these integration tests skip rather than fail —
    // the hermetic unit/property suites still cover the coordinator.
    let runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return None;
        }
    };
    match Registry::open(runtime, "artifacts") {
        Ok(r) => Some(Arc::new(r)),
        Err(e) => {
            eprintln!("skipping: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn quick_tuner(reg: &Registry) -> Tuner<'_> {
    Tuner::new(reg).with_measure_cfg(MeasureConfig::quick())
}

#[test]
fn exhaustive_tune_axpy_small() {
    let Some(reg) = registry() else { return };
    let tuner = quick_tuner(&reg);
    let mut strategy = Exhaustive::new();
    let outcome = tuner.tune("axpy", "n4096", &mut strategy, usize::MAX).unwrap();

    // Space: blocks {256,1024,4096} x unrolls {1,2,4} = 9 valid points.
    assert_eq!(outcome.evaluations(), 9);
    // Every variant passed the correctness gate (they all compute axpy).
    for v in &outcome.evaluated {
        let c = v.correctness.as_ref().expect("evaluated");
        assert!(c.ok, "variant {} failed gate: {c:?}", v.config_id);
        assert!(v.cost.is_finite());
    }
    // The default schedule was evaluated and reported.
    let d = outcome.default.as_ref().expect("default evaluated");
    assert_eq!(d.config_id, "b1024_u1");
    // Autotuned never loses to the un-annotated baseline.
    assert!(outcome.speedup() >= 1.0 - 1e-9);
    assert!(outcome.best_time() <= outcome.baseline_time() + 1e-12);
    // Sanity on the comparator ratio.
    assert!(outcome.vs_reference() > 0.0);
}

#[test]
fn budgeted_strategies_respect_budget_and_find_valid_best() {
    let Some(reg) = registry() else { return };
    let tuner = quick_tuner(&reg);
    let spec = tuner.spec("axpy", "n4096").unwrap();

    let mut anneal = Anneal::new(7);
    let outcome = tuner.tune("axpy", "n4096", &mut anneal, 4).unwrap();
    // 4 search evals + 1 forced default eval (deduped if revisited).
    assert!(outcome.evaluations() <= 5, "evals {}", outcome.evaluations());
    let best = outcome.best.as_ref().unwrap();
    assert!(spec.is_valid(&best.config));

    let mut hc = HillClimb::new(3);
    let outcome = tuner.tune("axpy", "n4096", &mut hc, 4).unwrap();
    assert!(outcome.evaluations() <= 5);

    let mut rnd = RandomSearch::new(11);
    let outcome = tuner.tune("axpy", "n4096", &mut rnd, 3).unwrap();
    assert!(outcome.evaluations() <= 4);
}

#[test]
fn warm_start_candidates_are_evaluated_first() {
    let Some(reg) = registry() else { return };
    let mut tuner = quick_tuner(&reg);
    let spec = tuner.spec("axpy", "n4096").unwrap();
    let cfg = spec.enumerate().into_iter().last().unwrap();
    tuner.warm_start = vec![cfg.clone()];
    // Budget 0: only the forced default + warm-start evals happen.
    let mut strategy = Exhaustive::new();
    let outcome = tuner.tune("axpy", "n4096", &mut strategy, 0).unwrap();
    assert_eq!(outcome.evaluations(), 2);
    assert!(outcome.evaluated.iter().any(|v| v.config == cfg));
}

#[test]
fn spec_matches_manifest_grid() {
    let Some(reg) = registry() else { return };
    let tuner = quick_tuner(&reg);
    let spec = tuner.spec("stencil2d", "m128_n128").unwrap();
    let (_, wl) = reg.find("stencil2d", "m128_n128").unwrap();
    // Every enumerated config has a pre-lowered artifact, and vice versa.
    let ids: Vec<String> = spec.enumerate().iter().map(|c| spec.config_id(c)).collect();
    let manifest_ids: Vec<&str> = wl.variants.iter().map(|v| v.id.as_str()).collect();
    assert_eq!(ids.len(), manifest_ids.len());
    for id in &ids {
        assert!(manifest_ids.contains(&id.as_str()), "{id} missing artifact");
    }
}

#[test]
fn annotation_spec_round_trips_against_manifest() {
    // An annotation block equivalent to the axpy manifest entry must
    // produce the same search space.
    let source = r#"
        /*@ tune kernel=axpy workload=n4096
            param block_size as b [256, 1024, 4096, 16384]
            param unroll as u [1, 2, 4]
            constraint block_size <= n
            constraint block_size % unroll == 0
        @*/
    "#;
    let ann = Annotation::parse(&extract_blocks(source)[0]).unwrap();
    let dims = [("n".to_string(), 4096i64)].into_iter().collect();
    let from_ann: TuningSpec = ann.to_spec("n4096", dims).unwrap();

    let Some(reg) = registry() else { return };
    let tuner = quick_tuner(&reg);
    let from_manifest = tuner.spec("axpy", "n4096").unwrap();

    let a: Vec<String> =
        from_ann.enumerate().iter().map(|c| from_ann.config_id(c)).collect();
    let b: Vec<String> = from_manifest
        .enumerate()
        .iter()
        .map(|c| from_manifest.config_id(c))
        .collect();
    assert_eq!(a, b);
}

#[test]
fn tuned_outputs_match_reference_everywhere() {
    // The correctness gate's own integrity: take the best variant, rerun
    // it, compare raw outputs to the baseline artifact.
    let Some(reg) = registry() else { return };
    let tuner = quick_tuner(&reg);
    let mut strategy = Exhaustive::new();
    let outcome = tuner.tune("dot", "n4096", &mut strategy, usize::MAX).unwrap();
    let best = outcome.best.as_ref().unwrap();

    let (_, wl) = reg.find("dot", "n4096").unwrap();
    let inputs = tuner.inputs("dot", "n4096").unwrap();
    let reference = reg.load(&wl.baseline).unwrap().run(&inputs).unwrap();
    let variant = wl.variant(&best.config_id).unwrap();
    let out = reg.load(&variant.path).unwrap().run(&inputs).unwrap();
    assert_eq!(out.len(), reference.len());
    for (o, r) in out.iter().zip(&reference) {
        assert!((o - r).abs() <= 1e-3 + 2e-4 * r.abs());
    }
}

#[test]
fn zero_tolerance_gates_reassociated_variants_gracefully() {
    // dot variants re-associate the reduction, so with a zero tolerance
    // most (often all) variants fail the gate.  The tuner must degrade
    // gracefully: gated variants get infinite cost, and if nothing
    // passes, the outcome falls back to the reference (speedup 1.0).
    let Some(reg) = registry() else { return };
    let mut tuner = quick_tuner(&reg);
    tuner.tolerance = portatune::coordinator::selection::Tolerance { rtol: 0.0, atol: 0.0 };
    let mut strategy = Exhaustive::new();
    let outcome = tuner.tune("dot", "n4096", &mut strategy, usize::MAX).unwrap();
    for v in &outcome.evaluated {
        let c = v.correctness.as_ref().unwrap();
        if !c.ok {
            assert!(v.cost.is_infinite(), "{} gated but finite cost", v.config_id);
        }
    }
    // Whatever happens, reported times are well-defined and positive.
    assert!(outcome.baseline_time() > 0.0);
    assert!(outcome.best_time() > 0.0);
    assert!(outcome.speedup() >= 0.99);
}

#[test]
fn corrupt_artifact_fails_cleanly_not_fatally() {
    // A variant whose artifact is garbage must surface as a failed
    // evaluation (infinite cost), not a crash of the whole tune.
    let Some(reg) = registry() else { return };
    let err = reg
        .runtime()
        .compile_text("definitely not HLO text {", "garbage")
        .err()
        .expect("garbage HLO must not compile");
    let msg = format!("{err:#}");
    assert!(msg.contains("garbage") || !msg.is_empty());
}

#[test]
fn neldermead_tunes_real_space() {
    use portatune::coordinator::search::NelderMead;
    let Some(reg) = registry() else { return };
    let tuner = quick_tuner(&reg);
    let mut nm = NelderMead::new(17);
    let outcome = tuner.tune("stencil2d", "m128_n128", &mut nm, 8).unwrap();
    assert!(outcome.evaluations() <= 9); // budget + forced default
    let spec = tuner.spec("stencil2d", "m128_n128").unwrap();
    assert!(spec.is_valid(&outcome.best.as_ref().unwrap().config));
}
