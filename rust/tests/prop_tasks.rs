//! Property tests for the leased task queue, driven by the crate's
//! deterministic RNG (no proptest in the pinned set).  The guarantees
//! under test are the ones the distributed worker fleet leans on:
//!
//! * an expired lease requeues its task **exactly once**, no matter
//!   how many expiry sweeps run or when;
//! * `complete` is idempotent — the first call settles, every retry
//!   reports a duplicate and changes nothing;
//! * a completed task is never re-leased;
//! * under an adversarial random interleaving of lease / heartbeat /
//!   complete / fail / expire, every task is completed at most once
//!   and nothing is ever lost (every enqueued task ends completed or
//!   dropped-after-max-attempts).

use std::collections::HashSet;

use portatune::service::scheduler::{
    CompleteOutcome, FailOutcome, StaleReason, TaskKind, TaskQueue, TuningTask, MAX_ATTEMPTS,
};
use portatune::util::rng::Rng;

fn task(rng: &mut Rng, i: usize) -> TuningTask {
    let kind = match rng.gen_range(3) {
        0 => TaskKind::Retune,
        1 => TaskKind::Sweep,
        _ => TaskKind::PortfolioRebuild,
    };
    TuningTask {
        kind,
        platform_key: format!("platform-{}", rng.gen_range(4)),
        kernel: format!("kernel-{i}"),
        tag: match kind {
            TaskKind::Retune => Some(format!("n{}", 1 << rng.gen_range(16))),
            _ => None,
        },
        reason: if rng.gen_range(4) == 0 {
            StaleReason::FingerprintDrift
        } else {
            StaleReason::TtlExpired { age_s: rng.gen_range(1_000_000) as u64 }
        },
        attempts: 0,
    }
}

#[test]
fn expired_lease_requeues_exactly_once_under_random_sweeps() {
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..50 {
        let mut q = TaskQueue::new(3600);
        let n = 1 + rng.gen_range(12);
        for i in 0..n {
            assert!(q.enqueue(task(&mut rng, i)));
        }
        // Lease everything with random TTLs, heartbeat a random subset
        // once, then run many random expiry sweeps past every horizon.
        let mut now = 1000u64;
        let mut leases = Vec::new();
        while let Some((id, _)) = q.lease(None, None, 1 + rng.gen_range(50) as u64, now) {
            leases.push(id);
        }
        assert_eq!(leases.len(), n);
        assert_eq!(q.len(), 0);
        for &id in &leases {
            if rng.gen_range(2) == 0 {
                assert!(q.heartbeat(id, now).is_some());
            }
        }
        let mut total_expired = 0;
        for _ in 0..20 {
            now += rng.gen_range(40) as u64;
            total_expired += q.expire(now);
        }
        now += 1000; // beyond every possible ttl + heartbeat
        total_expired += q.expire(now);
        total_expired += q.expire(now); // idempotent second sweep
        assert_eq!(total_expired, n, "each lease expires exactly once");
        assert_eq!(q.len(), n, "each task is back in pending exactly once");
        // The dead leases are really dead.
        for id in leases {
            assert!(q.heartbeat(id, now).is_none());
        }
    }
}

#[test]
fn complete_is_idempotent_and_completed_tasks_never_lease_again() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..50 {
        let mut q = TaskQueue::new(3600);
        let n = 1 + rng.gen_range(10);
        let mut identities = HashSet::new();
        for i in 0..n {
            let t = task(&mut rng, i);
            identities.insert(t.identity());
            assert!(q.enqueue(t));
        }
        let mut now = 0u64;
        let mut completed: HashSet<_> = HashSet::new();
        while let Some((id, t)) = q.lease(None, None, 60, now) {
            now += rng.gen_range(5) as u64;
            assert_eq!(q.complete(id), CompleteOutcome::Settled);
            assert!(
                completed.insert(t.identity()),
                "a completed identity was leased a second time"
            );
            // Every retry is a duplicate and must not resurrect it.
            for _ in 0..rng.gen_range(3) {
                assert_eq!(q.complete(id), CompleteOutcome::Duplicate);
            }
            assert_eq!(q.fail(id), FailOutcome::Duplicate);
        }
        assert_eq!(completed, identities, "everything drains exactly once");
        assert!(q.is_empty());
        assert_eq!(q.expire(u64::MAX / 2), 0, "nothing settled can expire");
        assert!(q.lease(None, None, 60, now).is_none());
    }
}

/// The adversarial interleaving: random workers lease, heartbeat,
/// complete, fail, crash (silently dropping their lease), while expiry
/// sweeps run at random times.  Model-checked invariants: a task
/// identity is completed at most once, completed and explicitly-
/// dropped sets stay disjoint, and at the end the queue is fully
/// drained — every identity was either completed, dropped by
/// exhausted `fail`s, or dropped by exhausted lease losses (expiry
/// charges attempts too); none is ever stuck pending/leased and none
/// executes twice.
#[test]
fn random_interleavings_neither_lose_nor_duplicate_work() {
    let mut rng = Rng::new(0xD15C0);
    for round in 0..30 {
        let mut q = TaskQueue::new(3600);
        let n = 2 + rng.gen_range(10);
        let mut identities = HashSet::new();
        for i in 0..n {
            let t = task(&mut rng, i);
            identities.insert(t.identity());
            assert!(q.enqueue(t));
        }
        let mut now = 0u64;
        let mut held: Vec<(u64, TuningTask)> = Vec::new();
        let mut completed: HashSet<_> = HashSet::new();
        let mut dropped: HashSet<_> = HashSet::new();
        for _step in 0..2000 {
            now += rng.gen_range(4) as u64;
            match rng.gen_range(10) {
                // Lease (short TTLs so crashes recover within the run).
                0..=3 => {
                    if let Some((id, t)) = q.lease(None, None, 1 + rng.gen_range(8) as u64, now)
                    {
                        held.push((id, t));
                    }
                }
                // Complete a held lease.
                4..=5 => {
                    if !held.is_empty() {
                        let (id, t) = held.swap_remove(rng.gen_range(held.len()));
                        if q.complete(id) == CompleteOutcome::Settled {
                            assert!(
                                completed.insert(t.identity()),
                                "round {round}: identity completed twice"
                            );
                        }
                    }
                }
                // Fail a held lease.
                6 => {
                    if !held.is_empty() {
                        let (id, t) = held.swap_remove(rng.gen_range(held.len()));
                        match q.fail(id) {
                            FailOutcome::Requeued => {}
                            FailOutcome::Dropped => {
                                dropped.insert(t.identity());
                            }
                            // The lease may have expired under us.
                            FailOutcome::Duplicate => {}
                            FailOutcome::Unknown => panic!("issued lease unknown"),
                        }
                    }
                }
                // Heartbeat a held lease (may already be expired).
                7 => {
                    if !held.is_empty() {
                        let idx = rng.gen_range(held.len());
                        let _ = q.heartbeat(held[idx].0, now);
                    }
                }
                // Crash a worker: silently forget the lease.
                8 => {
                    if !held.is_empty() {
                        let idx = rng.gen_range(held.len());
                        held.swap_remove(idx);
                    }
                }
                // Expiry sweep (may drop tasks whose attempts ran out).
                _ => {
                    q.expire(now);
                }
            }
        }
        // Drain whatever is left synchronously.  Any lease can still
        // expire at most MAX_ATTEMPTS times total, so a bounded number
        // of expire+lease passes fully empties the queue.
        for _ in 0..=MAX_ATTEMPTS {
            now += 10_000;
            q.expire(now);
            while let Some((id, t)) = q.lease(None, None, 60, now) {
                if q.complete(id) == CompleteOutcome::Settled {
                    assert!(
                        completed.insert(t.identity()),
                        "round {round}: identity completed twice in drain"
                    );
                }
            }
        }
        assert!(q.is_empty(), "round {round}: tasks stuck pending");
        for identity in &identities {
            assert!(
                !(completed.contains(identity) && dropped.contains(identity)),
                "round {round}: identity both completed and dropped: {identity:?}"
            );
        }
    }
}
