//! Property-based tests over coordinator invariants.
//!
//! The offline dependency set has no proptest, so properties are driven
//! by the crate's own deterministic RNG: hundreds of randomized cases
//! per property, fully reproducible (fixed master seeds), with the
//! failing case's seed printed on assert.  These cover the invariants
//! DESIGN.md §7 lists: search strategies only emit in-domain configs and
//! respect budgets, the constraint evaluator and JSON parser are total
//! (error, never panic), stats invariants, and perf-DB round-trips.

use std::collections::BTreeMap;

use portatune::coordinator::constraint::{check, Expr};
use portatune::coordinator::measure::{race_samplers, MeasureConfig};
use portatune::coordinator::search::{
    drive_batched, Anneal, Exhaustive, Genetic, HillClimb, RandomSearch, SearchStrategy,
};
use portatune::coordinator::spec::{Config, TuningSpec};
use portatune::runtime::registry::ParamDef;
use portatune::util::json;
use portatune::util::rng::Rng;
use portatune::util::stats::{reject_outliers, Summary};

/// Random spec: 1–3 params, domains of 2–6 power-of-two-ish values, with
/// the standard divisibility/bound constraint shapes.
fn random_spec(rng: &mut Rng) -> TuningSpec {
    let nparams = 1 + rng.gen_range(3);
    let names = ["alpha", "beta", "gamma"];
    let abbrevs = ["a", "b", "g"];
    let mut params = Vec::new();
    for i in 0..nparams {
        let base = 1usize << (3 + rng.gen_range(4));
        let len = 2 + rng.gen_range(5);
        let values: Vec<i64> = (0..len).map(|j| (base << j) as i64).collect();
        params.push(ParamDef {
            name: names[i].into(),
            abbrev: abbrevs[i].into(),
            values,
        });
    }
    let n = 1i64 << (10 + rng.gen_range(8));
    let mut constraints = vec![format!("alpha <= n")];
    if nparams >= 2 {
        constraints.push("alpha % beta == 0 || beta <= alpha".to_string());
    }
    TuningSpec::new(
        "prop",
        "t",
        params,
        &constraints,
        [("n".to_string(), n)].into_iter().collect(),
    )
    .unwrap()
}

#[test]
fn prop_enumerate_only_valid_unique_configs() {
    let mut master = Rng::new(0xE1);
    for case in 0..60 {
        let spec = random_spec(&mut master);
        let all = spec.enumerate();
        let mut ids: Vec<String> = all.iter().map(|c| spec.config_id(c)).collect();
        for c in &all {
            assert!(spec.is_valid(c), "case {case}: invalid enumerated config {c:?}");
        }
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "case {case}: duplicate config ids");
        assert!(all.len() <= spec.raw_space_size());
    }
}

#[test]
fn prop_random_config_and_neighbors_valid() {
    let mut master = Rng::new(0xE2);
    for case in 0..60 {
        let spec = random_spec(&mut master);
        let mut rng = Rng::new(case as u64 + 1);
        if let Some(c) = spec.random_config(&mut rng, 200) {
            assert!(spec.is_valid(&c), "case {case}");
            for nb in spec.neighbors(&c) {
                assert!(spec.is_valid(&nb), "case {case}: invalid neighbor");
                // Exactly one parameter differs, by one domain position.
                let ci = spec.index_of(&c).unwrap();
                let ni = spec.index_of(&nb).unwrap();
                let diffs: Vec<_> = ci
                    .iter()
                    .zip(&ni)
                    .filter(|(a, b)| a != b)
                    .map(|(a, b)| (*a as i64 - *b as i64).abs())
                    .collect();
                assert_eq!(diffs, vec![1], "case {case}: non-unit move");
            }
        }
    }
}

#[test]
fn prop_index_round_trip() {
    let mut master = Rng::new(0xE3);
    for _ in 0..40 {
        let spec = random_spec(&mut master);
        for c in spec.enumerate() {
            let idx = spec.index_of(&c).unwrap();
            assert_eq!(spec.config_at(&idx), c);
        }
    }
}

fn synthetic_cost(spec: &TuningSpec, c: &Config, salt: u64) -> f64 {
    // Deterministic pseudo-random positive surface.
    let id = spec.config_id(c);
    let mut h: u64 = 0xcbf29ce484222325 ^ salt;
    for b in id.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    1e-6 + (h % 10_000) as f64 * 1e-7
}

#[test]
fn prop_all_strategies_respect_budget_and_validity() {
    let mut master = Rng::new(0xE4);
    for case in 0..25u64 {
        let spec = random_spec(&mut master);
        let space = spec.enumerate().len();
        if space == 0 {
            continue;
        }
        let budget = 1 + (case as usize % (space + 3));
        let strategies: Vec<Box<dyn SearchStrategy>> = vec![
            Box::new(Exhaustive::new()),
            Box::new(RandomSearch::new(case + 1)),
            Box::new(HillClimb::new(case + 1)),
            Box::new(Anneal::new(case + 1)),
            Box::new(Genetic::new(case + 1)),
        ];
        for mut s in strategies {
            let spec2 = spec.clone();
            let mut eval = move |c: &Config| {
                assert!(spec2.is_valid(c), "strategy evaluated invalid config");
                synthetic_cost(&spec2, c, case)
            };
            let r = s.run(&spec, budget, &mut eval);
            assert!(
                r.evaluations() <= budget,
                "{} exceeded budget: {} > {budget}",
                s.name(),
                r.evaluations()
            );
            // best == min over history.
            if let Some((_, best_cost)) = &r.best {
                let min = r
                    .history
                    .iter()
                    .map(|e| e.cost)
                    .fold(f64::INFINITY, f64::min);
                assert_eq!(*best_cost, min, "{}", s.name());
            }
            // History configs unique.
            let mut ids: Vec<String> =
                r.history.iter().map(|e| spec.config_id(&e.config)).collect();
            let n = ids.len();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), n, "{} repeated evaluations", s.name());
        }
    }
}

#[test]
fn prop_exhaustive_with_full_budget_finds_global_min() {
    let mut master = Rng::new(0xE5);
    for case in 0..25u64 {
        let spec = random_spec(&mut master);
        if spec.enumerate().is_empty() {
            continue; // fully constrained-away space: nothing to find
        }
        let spec2 = spec.clone();
        let mut eval = move |c: &Config| synthetic_cost(&spec2, c, case);
        let mut s = Exhaustive::new();
        let r = s.run(&spec, usize::MAX, &mut eval);
        let true_min = spec
            .enumerate()
            .iter()
            .map(|c| synthetic_cost(&spec, c, case))
            .fold(f64::INFINITY, f64::min);
        assert_eq!(r.best.unwrap().1, true_min, "case {case}");
    }
}

#[test]
fn prop_constraint_evaluator_is_total() {
    // Random well-formed expressions evaluate to Ok or a structured
    // error — never panic.
    let mut rng = Rng::new(0xE6);
    let atoms = ["alpha", "beta", "n", "0", "1", "7", "4096"];
    let bins = ["+", "-", "*", "/", "%", "==", "!=", "<", "<=", ">", ">=", "&&", "||"];
    for _ in 0..500 {
        let mut expr = atoms[rng.gen_range(atoms.len())].to_string();
        for _ in 0..rng.gen_range(5) {
            expr = format!(
                "({expr} {} {})",
                bins[rng.gen_range(bins.len())],
                atoms[rng.gen_range(atoms.len())]
            );
        }
        let env: BTreeMap<String, i64> = [
            ("alpha".to_string(), rng.gen_range(100) as i64),
            ("beta".to_string(), rng.gen_range(100) as i64),
            ("n".to_string(), 4096),
        ]
        .into_iter()
        .collect();
        let _ = check(&expr, &env); // must not panic
    }
}

#[test]
fn prop_constraint_parser_never_panics_on_garbage() {
    let mut rng = Rng::new(0xE7);
    let charset: Vec<char> =
        "abn0159 ()+-*/%<>=!&| \t#@$".chars().collect();
    for _ in 0..1000 {
        let len = rng.gen_range(24);
        let s: String = (0..len).map(|_| charset[rng.gen_range(charset.len())]).collect();
        let _ = Expr::parse(&s); // must not panic
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    let mut rng = Rng::new(0xE8);
    let charset: Vec<char> = "{}[]\",:0123456789.eE+-truefalsn\\ ".chars().collect();
    for _ in 0..1000 {
        let len = rng.gen_range(40);
        let s: String = (0..len).map(|_| charset[rng.gen_range(charset.len())]).collect();
        let _ = json::parse(&s); // must not panic
    }
}

#[test]
fn prop_json_round_trip_random_documents() {
    fn random_json(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.gen_range(2) == 0),
            2 => json::int(rng.next_u64() as i64 % 1_000_000),
            3 => {
                let len = rng.gen_range(8);
                json::s(&(0..len)
                    .map(|_| char::from(b'a' + rng.gen_range(26) as u8))
                    .collect::<String>())
            }
            4 => json::Json::Arr(
                (0..rng.gen_range(4)).map(|_| random_json(rng, depth - 1)).collect(),
            ),
            _ => json::Json::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(0xE9);
    for _ in 0..200 {
        let v = random_json(&mut rng, 3);
        assert_eq!(json::parse(&v.pretty()).unwrap(), v);
        assert_eq!(json::parse(&v.compact()).unwrap(), v);
    }
}

#[test]
fn prop_stats_invariants() {
    let mut rng = Rng::new(0xEA);
    for _ in 0..300 {
        let n = 1 + rng.gen_range(40);
        let samples: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10.0 + 1e-9).collect();
        let s = Summary::from_samples(&samples).unwrap();
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.min <= s.mean && s.mean <= s.max);
        assert!(s.mad >= 0.0 && s.stddev >= 0.0);
        let kept = reject_outliers(&samples, 5.0);
        assert!(!kept.is_empty());
        assert!(kept.iter().all(|x| samples.contains(x)));
    }
}

fn race_cfg() -> MeasureConfig {
    MeasureConfig {
        warmup: 0,
        reps: 7,
        target_rel_spread: 0.10,
        max_reps: 28,
        outlier_k: 0.0,
        race_min_reps: 3,
    }
}

fn constant_lanes(costs: &[f64]) -> Vec<Box<dyn FnMut() -> anyhow::Result<f64> + '_>> {
    costs
        .iter()
        .map(|&c| Box::new(move || Ok(c)) as Box<dyn FnMut() -> anyhow::Result<f64> + '_>)
        .collect()
}

#[test]
fn prop_race_matches_full_measure_winner() {
    // On deterministic cost surfaces the racing harness must select the
    // exact variant that full per-candidate measurement would — early
    // termination may only cut candidates that provably cannot win.
    let mut rng = Rng::new(0xEC);
    for case in 0..100 {
        let n = 2 + rng.gen_range(10);
        let costs: Vec<f64> = (0..n).map(|_| 1e-4 + rng.next_f64() * 1e-2).collect();
        let mut lanes = constant_lanes(&costs);
        let out = race_samplers(&mut lanes, &race_cfg(), None).unwrap();
        let argmin = costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i);
        assert_eq!(out.winner, argmin, "case {case}: race winner diverged");
        let w = out.winner.unwrap();
        let measured = out.measurements[w].as_ref().unwrap().cost();
        assert!(
            (measured - costs[w]).abs() < 1e-12,
            "case {case}: winner cost {measured} != true {}",
            costs[w]
        );
    }
}

#[test]
fn prop_race_saves_at_least_30pct_reps() {
    // The acceptance bar: on batches of ≥ 4 distinct candidates the
    // cutoff spends ≤ 70% of the serial pipeline's timed repetitions.
    let mut rng = Rng::new(0xED);
    for case in 0..50 {
        let n = 4 + rng.gen_range(8);
        let costs: Vec<f64> = (0..n).map(|_| 1e-4 + rng.next_f64() * 1e-2).collect();
        let mut lanes = constant_lanes(&costs);
        let cfg = race_cfg();
        let out = race_samplers(&mut lanes, &cfg, None).unwrap();
        let serial = (n * cfg.reps) as u64;
        assert!(
            out.reps_timed as f64 <= 0.7 * serial as f64,
            "case {case}: race spent {} of serial {serial} reps",
            out.reps_timed
        );
        assert_eq!(out.reps_timed + out.reps_saved, serial, "case {case}");
        assert_eq!(out.pruned as usize, n - 1, "case {case}: all losers cut");
    }
}

#[test]
fn prop_batched_drive_matches_serial_exhaustive_winner() {
    // drive_batched over exhaustive with full budget must reproduce the
    // sequential sweep exactly: same coverage, same winner.
    let mut master = Rng::new(0xEE);
    for case in 0..25u64 {
        let spec = random_spec(&mut master);
        if spec.enumerate().is_empty() {
            continue;
        }
        let spec2 = spec.clone();
        let mut eval = move |c: &Config| synthetic_cost(&spec2, c, case);
        let mut serial_strategy = Exhaustive::new();
        let serial = serial_strategy.run(&spec, usize::MAX, &mut eval);

        for batch in [2usize, 4, 7] {
            let spec3 = spec.clone();
            let mut eval_batch =
                move |b: &[Config]| -> Vec<f64> {
                    b.iter().map(|c| synthetic_cost(&spec3, c, case)).collect()
                };
            let mut s = Exhaustive::new();
            let r = drive_batched(&mut s, &spec, usize::MAX, batch, &[], &mut eval_batch);
            assert_eq!(
                r.best.as_ref().map(|(c, _)| spec.config_id(c)),
                serial.best.as_ref().map(|(c, _)| spec.config_id(c)),
                "case {case} batch {batch}: winner diverged"
            );
            assert_eq!(r.evaluations(), serial.evaluations(), "case {case} batch {batch}");
        }
    }
}

#[test]
fn prop_batch_proposal_respects_budget_dedupe_and_validity() {
    // The batched driver's dedupe must bound unique evaluations by the
    // budget for every batch-capable strategy, with valid-only configs
    // and a best that matches the history minimum.
    let mut master = Rng::new(0xEF);
    for case in 0..20u64 {
        let spec = random_spec(&mut master);
        let space = spec.enumerate().len();
        if space == 0 {
            continue;
        }
        let budget = 1 + (case as usize % (space + 3));
        for batch in [1usize, 3, 5] {
            let strategies: Vec<Box<dyn SearchStrategy>> = vec![
                Box::new(Exhaustive::new()),
                Box::new(RandomSearch::new(case + 1)),
                Box::new(HillClimb::new(case + 1)),
                Box::new(Genetic::new(case + 1)),
            ];
            for mut s in strategies {
                assert!(s.supports_batch(), "{} must support batching", s.name());
                let spec2 = spec.clone();
                let mut eval_batch = move |b: &[Config]| -> Vec<f64> {
                    b.iter()
                        .map(|c| {
                            assert!(spec2.is_valid(c), "batched eval got invalid config");
                            synthetic_cost(&spec2, c, case)
                        })
                        .collect()
                };
                let r = drive_batched(&mut *s, &spec, budget, batch, &[], &mut eval_batch);
                assert!(
                    r.evaluations() <= budget,
                    "{} batch {batch} exceeded budget: {} > {budget}",
                    s.name(),
                    r.evaluations()
                );
                let mut ids: Vec<String> =
                    r.history.iter().map(|e| spec.config_id(&e.config)).collect();
                let n = ids.len();
                ids.sort();
                ids.dedup();
                assert_eq!(ids.len(), n, "{} repeated evaluations under batching", s.name());
                if let Some((_, best)) = &r.best {
                    let min = r.history.iter().map(|e| e.cost).fold(f64::INFINITY, f64::min);
                    assert_eq!(*best, min, "{}", s.name());
                }
            }
        }
    }
}

#[test]
fn prop_config_id_is_injective_over_space() {
    let mut master = Rng::new(0xEB);
    for _ in 0..30 {
        let spec = random_spec(&mut master);
        let mut seen = std::collections::HashMap::new();
        for c in spec.enumerate() {
            let id = spec.config_id(&c);
            if let Some(prev) = seen.insert(id.clone(), c.clone()) {
                panic!("config id {id} maps to both {prev:?} and {c:?}");
            }
        }
    }
}
