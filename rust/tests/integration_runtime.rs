//! Integration tests over real AOT artifacts: manifest → PJRT compile →
//! execute → numerics vs host oracles.  Requires `make artifacts`.

use std::sync::Arc;

use portatune::runtime::{Registry, Runtime, TensorData};
use portatune::util::rng::Rng;
use portatune::workload::{self, spmv, stencil};

fn registry() -> Option<Arc<Registry>> {
    // Build-time gate: without the real XLA backend (or without AOT
    // artifacts on disk) these integration tests skip rather than fail —
    // the hermetic unit/property suites still cover the coordinator.
    let runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return None;
        }
    };
    match Registry::open(runtime, "artifacts") {
        Ok(r) => Some(Arc::new(r)),
        Err(e) => {
            eprintln!("skipping: {e:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_covers_all_families() {
    let Some(reg) = registry() else { return };
    let names: Vec<&str> = reg.manifest().kernels.iter().map(|k| k.name.as_str()).collect();
    for expected in ["axpy", "dot", "triad", "stencil2d", "jacobi", "spmv_ell", "matmul"] {
        assert!(names.contains(&expected), "missing kernel {expected}");
    }
    // Every workload declares a default variant with an artifact.
    for k in &reg.manifest().kernels {
        for w in &k.workloads {
            let d = w.default.as_deref().expect("default declared");
            assert!(w.variant(d).is_some(), "{}/{} default {d} has no artifact", k.name, w.tag);
        }
    }
}

#[test]
fn axpy_baseline_matches_host_oracle() {
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("axpy", "n4096").unwrap();
    let inputs = workload::inputs_for("axpy", wl, 7).unwrap();
    let exe = reg.load(&wl.baseline).unwrap();
    let out = exe.run(&inputs).unwrap();

    let a = inputs[0].as_f32().unwrap()[0];
    let x = inputs[1].as_f32().unwrap();
    let y = inputs[2].as_f32().unwrap();
    assert_eq!(out.len(), 4096);
    for i in 0..4096 {
        let expect = a * x[i] + y[i];
        assert!((out[i] - expect).abs() < 1e-5, "i={i}: {} vs {expect}", out[i]);
    }
}

#[test]
fn axpy_variants_match_baseline() {
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("axpy", "n4096").unwrap();
    let inputs = workload::inputs_for("axpy", wl, 13).unwrap();
    let reference = reg.load(&wl.baseline).unwrap().run(&inputs).unwrap();
    for v in &wl.variants {
        let out = reg.load(&v.path).unwrap().run(&inputs).unwrap();
        assert_eq!(out.len(), reference.len(), "{}", v.id);
        for i in 0..out.len() {
            assert!(
                (out[i] - reference[i]).abs() < 1e-4,
                "variant {} diverges at {i}",
                v.id
            );
        }
    }
}

#[test]
fn dot_artifact_is_scalar_and_correct() {
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("dot", "n4096").unwrap();
    let inputs = workload::inputs_for("dot", wl, 3).unwrap();
    let out = reg.load(&wl.baseline).unwrap().run(&inputs).unwrap();
    assert_eq!(out.len(), 1);
    let x = inputs[0].as_f32().unwrap();
    let y = inputs[1].as_f32().unwrap();
    let expect: f64 = x.iter().zip(y).map(|(&a, &b)| a as f64 * b as f64).sum();
    assert!(
        (out[0] as f64 - expect).abs() < 1e-2 * expect.abs().max(1.0),
        "{} vs {expect}",
        out[0]
    );
}

#[test]
fn spmv_artifact_matches_host_reference() {
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("spmv_ell", "k32_nrows4096").unwrap();
    let inputs = workload::inputs_for("spmv_ell", wl, 21).unwrap();
    let out = reg.load(&wl.baseline).unwrap().run(&inputs).unwrap();
    let v = inputs[0].as_f32().unwrap();
    let ci = inputs[1].as_i32().unwrap();
    let x = inputs[2].as_f32().unwrap();
    let expect = spmv::spmv_reference(v, ci, x, 4096, 32);
    for i in 0..4096 {
        assert!((out[i] - expect[i]).abs() < 1e-3, "row {i}");
    }
    // A tuned variant agrees too.
    let var = &wl.variants[0];
    let out2 = reg.load(&var.path).unwrap().run(&inputs).unwrap();
    for i in 0..4096 {
        assert!((out2[i] - expect[i]).abs() < 1e-3, "variant row {i}");
    }
}

#[test]
fn jacobi_step_preserves_boundary_and_diffuses() {
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("jacobi", "m256_n256").unwrap();
    let grid = stencil::hot_boundary_grid(256, 256, 1.0);
    let exe = reg.load(&wl.baseline).unwrap();
    let out = exe.run(&[grid.clone()]).unwrap();
    let g0 = grid.as_f32().unwrap();
    let cols = 258;
    // Boundary unchanged.
    for j in 0..cols {
        assert_eq!(out[j], g0[j]);
        assert_eq!(out[257 * cols + j], g0[257 * cols + j]);
    }
    // First interior ring received heat; deep interior still cold after
    // one sweep.
    assert!(out[cols + 1] > 0.0);
    assert_eq!(out[129 * cols + 129], 0.0);
    // Mean distance to the all-hot steady state must shrink over sweeps
    // (max-norm stays 1.0 until the front reaches the center, so use the
    // mean).
    let mean_dist = |g: &[f32]| -> f64 {
        let mut acc = 0.0f64;
        for i in 1..=256 {
            for j in 1..=256 {
                acc += (g[i * cols + j] - 1.0).abs() as f64;
            }
        }
        acc / (256.0 * 256.0)
    };
    let d0 = mean_dist(g0);
    let mut cur: TensorData = TensorData::f32(vec![258, 258], out);
    for _ in 0..9 {
        let next = exe.run(&[cur.clone()]).unwrap();
        cur = TensorData::f32(vec![258, 258], next);
    }
    let d10 = mean_dist(cur.as_f32().unwrap());
    assert!(d10 < d0, "no diffusion progress: {d10} !< {d0}");
}

#[test]
fn matmul_artifact_matches_host_oracle() {
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("matmul", "k256_m256_n256").unwrap();
    let inputs = workload::inputs_for("matmul", wl, 5).unwrap();
    let out = reg.load(&wl.baseline).unwrap().run(&inputs).unwrap();
    let a = inputs[0].as_f32().unwrap();
    let b = inputs[1].as_f32().unwrap();
    // Spot-check a scattered sample of entries.
    let mut rng = Rng::new(99);
    for _ in 0..64 {
        let i = rng.gen_range(256);
        let j = rng.gen_range(256);
        let mut acc = 0.0f64;
        for k in 0..256 {
            acc += a[i * 256 + k] as f64 * b[k * 256 + j] as f64;
        }
        let got = out[i * 256 + j] as f64;
        assert!(
            (got - acc).abs() < 1e-2 * acc.abs().max(1.0),
            "C[{i},{j}] = {got} vs {acc}"
        );
    }
}

#[test]
fn compile_cache_hits_do_not_recompile() {
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("axpy", "n4096").unwrap();
    let before = reg.compile_count();
    let _ = reg.load(&wl.baseline).unwrap();
    let mid = reg.compile_count();
    let _ = reg.load(&wl.baseline).unwrap();
    let after = reg.compile_count();
    assert_eq!(mid, before + 1);
    assert_eq!(after, mid, "second load must hit the cache");
}

#[test]
fn missing_artifact_errors_cleanly() {
    let Some(reg) = registry() else { return };
    assert!(reg.load("nonexistent/path.hlo.txt").is_err());
    assert!(reg.find("axpy", "bogus").is_err());
    assert!(reg.find("bogus", "n4096").is_err());
}

#[test]
fn untupled_jacobi_twin_agrees_with_tupled() {
    use portatune::runtime::registry::untupled_path;
    let Some(reg) = registry() else { return };
    let (_, wl) = reg.find("jacobi", "m256_n256").unwrap();
    assert!(wl.untupled, "jacobi must declare untupled twins");
    let grid = stencil::hot_boundary_grid(256, 256, 1.0);

    let tupled = reg.load(&wl.baseline).unwrap().run(&[grid.clone()]).unwrap();

    // Device-resident path: upload, run over buffers, download.
    let nt = reg.load(&untupled_path(&wl.baseline)).unwrap();
    let buf = reg
        .runtime()
        .buffer_from_f32(grid.as_f32().unwrap(), &[258, 258])
        .unwrap();
    let out_buf = nt.run_buffers(&[&buf]).unwrap();
    let out = out_buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap();

    assert_eq!(out.len(), tupled.len());
    for (a, b) in out.iter().zip(&tupled) {
        assert_eq!(a, b, "untupled twin must be bit-identical");
    }
}

#[test]
fn untupled_path_convention() {
    use portatune::runtime::registry::untupled_path;
    assert_eq!(untupled_path("jacobi/m256_n256/base.hlo.txt"), "jacobi/m256_n256/base.nt.hlo.txt");
    assert_eq!(untupled_path("weird"), "weird.nt");
}
