//! Serve-path throughput: what a lookup costs on each of the daemon's
//! paths, and how the snapshot read path scales under contention.
//!
//! * **snapshot x1** — single-threaded lookups: every request clones
//!   the published `Arc<ServeSnapshot>` (a read-lock held for
//!   nanoseconds) and answers from the immutable index.
//! * **snapshot xN** — the same traffic from N client threads hammering
//!   one shared server.  Because readers never take a writer lock, the
//!   aggregate rate must *scale* with thread count instead of
//!   flatlining on a mutex; the acceptance bar is ≥ 2× from 1 → 4
//!   threads whenever the machine actually has ≥ 4 cores (on smaller
//!   machines the gate prints a skip note instead of failing).
//! * **transfer miss** — deploy for a never-seen platform: scores
//!   fingerprint similarity over every shard in the snapshot and ranks
//!   candidates.  The slowest path by design; it exists so a fresh
//!   platform gets a warm start instead of nothing.
//! * **lease cycle** — one full worker checkout
//!   (task-lease → heartbeat → complete) against a pre-filled queue:
//!   the fleet-coordination overhead per task, which must be noise
//!   next to the minutes a sweep or re-tune actually takes.
//!
//! Fully hermetic (no XLA, no artifacts): the store is synthesized into
//! a temp dir.  Machine-readable tail line: `JSON: {...}` with
//! lookups/sec per path plus per-path latency percentiles from the
//! shared telemetry histogram (`portatune::obs`): p50/p95/p99 are
//! log-scaled bucket upper bounds, at most 25% above the true value.
//!
//! Run: `cargo bench --bench serve_throughput` (BENCH_QUICK=1 to shrink).

use std::time::Instant;

use portatune::coordinator::perfdb::{DbEntry, ShardedDb};
use portatune::coordinator::platform::Fingerprint;
use portatune::obs::Histogram;
use portatune::report::Table;
use portatune::service::{Request, ServeOpts, Server};
use portatune::util::json::{self, Json};

/// Synthetic platform fleet: distinct SIMD sets and cache geometries so
/// the transfer ranking has real work to do.
fn synth_fingerprint(i: usize) -> Fingerprint {
    let isa_tiers: &[&[&str]] = &[
        &["sse2"],
        &["sse2", "sse4_2"],
        &["sse2", "sse4_2", "avx"],
        &["sse2", "sse4_2", "avx", "avx2", "fma"],
        &["sse2", "sse4_2", "avx", "avx2", "avx512f", "fma"],
        &["neon"],
    ];
    let simd = isa_tiers[i % isa_tiers.len()];
    Fingerprint {
        cpu_model: format!("Synth CPU {i}"),
        num_cpus: 1 << (i % 6),
        simd: simd.iter().map(|s| s.to_string()).collect(),
        cache_l1d_kb: 32 << (i % 2),
        cache_l2_kb: 256 << (i % 4),
        cache_l3_kb: if i % 5 == 0 { 0 } else { 4096 << (i % 3) },
        os: "linux".to_string(),
    }
}

fn synth_entry(platform_key: &str, kernel: &str, tag: &str, i: usize) -> DbEntry {
    DbEntry {
        platform_key: platform_key.to_string(),
        kernel: kernel.to_string(),
        tag: tag.to_string(),
        best_params: [
            ("block_size".to_string(), 1i64 << (6 + i % 5)),
            ("unroll".to_string(), 1i64 << (i % 3)),
        ]
        .into_iter()
        .collect(),
        best_config_id: format!("b{}_u{}", 1 << (6 + i % 5), 1 << (i % 3)),
        best_time_s: 1e-3 / (1.0 + i as f64 * 0.1),
        baseline_time_s: 2e-3,
        reference_time_s: 9e-4,
        evaluations: 16,
        strategy: "exhaustive".to_string(),
        // Ancient on purpose: the lookup paths never read this, and it
        // lets the lease-cycle section below treat every frontier as
        // stale without racing wall-clock time.
        recorded_at: 1000,
    }
}

const KERNELS: &[(&str, &str)] =
    &[("axpy", "n4096"), ("axpy", "n65536"), ("dot", "n4096"), ("spmv_ell", "k32")];

fn lookup_req(keys: &[String], i: usize) -> Request {
    let (kernel, tag) = KERNELS[i % KERNELS.len()];
    Request::Lookup {
        platform: Some(keys[i % keys.len()].clone()),
        kernel: kernel.to_string(),
        workload: tag.to_string(),
    }
}

/// Time `n` calls of `f`; returns calls/sec plus the per-call latency
/// distribution (µs) in the shared telemetry bucket scheme.
fn rate(n: usize, mut f: impl FnMut(usize)) -> (f64, Histogram) {
    let hist = Histogram::new();
    let t0 = Instant::now();
    for i in 0..n {
        let call = Instant::now();
        f(i);
        hist.record(call.elapsed().as_micros() as u64);
    }
    (n as f64 / t0.elapsed().as_secs_f64().max(1e-9), hist)
}

/// The contended phase: `threads` client threads share one server and
/// hammer snapshot lookups.  The histogram is the shared telemetry type
/// (atomic buckets), so all threads record into it concurrently —
/// exactly how the daemon's own latency metrics work.
fn contended_rate(
    srv: &Server,
    threads: usize,
    per_thread: usize,
    keys: &[String],
) -> (f64, Histogram) {
    let hist = Histogram::new();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let hist = &hist;
            s.spawn(move || {
                for i in 0..per_thread {
                    let call = Instant::now();
                    let reply = srv.handle_request(&lookup_req(keys, t * per_thread + i));
                    assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
                    hist.record(call.elapsed().as_micros() as u64);
                }
            });
        }
    });
    (
        (threads * per_thread) as f64 / t0.elapsed().as_secs_f64().max(1e-9),
        hist,
    )
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (platforms, per_thread_n, transfer_n) =
        if quick { (8, 5_000, 50) } else { (24, 50_000, 300) };

    let dir = std::env::temp_dir()
        .join(format!("portatune-servebench-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let db = ShardedDb::open(&dir)?;
    let mut keys = Vec::new();
    for i in 0..platforms {
        let fp = synth_fingerprint(i);
        let key = fp.key();
        for (j, (kernel, tag)) in KERNELS.iter().enumerate() {
            db.record(Some(&fp), synth_entry(&key, kernel, tag, i + j))?;
        }
        keys.push(key);
    }
    println!(
        "serve-throughput bench — {} platforms x {} keys, shards in {}",
        platforms,
        KERNELS.len(),
        dir.display()
    );

    let host = Fingerprint::detect();
    let srv = Server::new(db.clone(), host.clone(), ServeOpts::default());

    // Snapshot reads, uncontended and contended.  Same total traffic
    // shape; only the thread count changes.
    let (one_per_s, one_hist) = contended_rate(&srv, 1, per_thread_n, &keys);
    let (four_per_s, four_hist) = contended_rate(&srv, 4, per_thread_n, &keys);

    // Transfer miss: a platform the store has never seen, full
    // similarity ranking over every shard.
    let fresh = Fingerprint {
        cpu_model: "Never Seen CPU".to_string(),
        num_cpus: 12,
        simd: vec!["sse2".into(), "avx".into(), "avx2".into()],
        cache_l1d_kb: 48,
        cache_l2_kb: 2048,
        cache_l3_kb: 30720,
        os: "linux".to_string(),
    };
    let (transfer_per_s, transfer_hist) = rate(transfer_n, |i| {
        let (kernel, tag) = KERNELS[i % KERNELS.len()];
        let reply = srv.handle_request(&Request::Deploy {
            platform: Some("fresh-platform-under-test".to_string()),
            kernel: kernel.to_string(),
            workload: tag.to_string(),
            fingerprint: Some(fresh.clone()),
        });
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("transfer"));
        assert!(
            reply.get("count").and_then(Json::as_i64).unwrap_or(0) > 0,
            "a fresh platform must get transfer candidates, not an empty miss"
        );
    });

    // Lease cycle: every synthesized entry is ancient (recorded_at
    // 1000), so one scan fills the queue; measure full
    // lease → heartbeat → complete round trips against it.
    let lease_srv = Server::new(db.clone(), host.clone(), ServeOpts::default());
    let queued = lease_srv.scan_once()?;
    let lease_n = queued.min(if quick { 50 } else { 300 });
    let (lease_per_s, lease_hist) = rate(lease_n, |_| {
        let reply = lease_srv.handle_request(&Request::TaskLease {
            kind: None,
            platform: None,
            ttl_s: Some(600),
        });
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true), "queue ran dry");
        let lease_id = reply.get("lease_id").and_then(Json::as_u64).unwrap();
        let reply = lease_srv.handle_request(&Request::TaskHeartbeat { lease_id });
        assert_eq!(reply.get("extended").and_then(Json::as_bool), Some(true));
        let reply =
            lease_srv.handle_request(&Request::TaskComplete { lease_id, request_id: None });
        assert_eq!(reply.get("settled").and_then(Json::as_bool), Some(true));
    });

    let mut t = Table::new(&["path", "lookups/sec", "p50 us", "p95 us", "p99 us", "vs x1"]);
    for (name, per_s, hist) in [
        ("snapshot x1", one_per_s, &one_hist),
        ("snapshot x4", four_per_s, &four_hist),
        ("transfer miss", transfer_per_s, &transfer_hist),
        ("lease cycle", lease_per_s, &lease_hist),
    ] {
        t.row(vec![
            name.to_string(),
            format!("{per_s:.0}"),
            hist.quantile(0.50).to_string(),
            hist.quantile(0.95).to_string(),
            hist.quantile(0.99).to_string(),
            format!("{:.1}x", per_s / one_per_s),
        ]);
    }
    print!("{}", t.render());

    // The scaling gate only means something on a machine that can run
    // the 4 client threads in parallel; on smaller machines (1-2 core
    // CI runners) the 4-thread rate legitimately equals the 1-thread
    // rate, so the bar is reported but not enforced.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let scaling = four_per_s / one_per_s;
    let gate_enforced = cores >= 4;
    let mut acceptance_failed = false;
    if !gate_enforced {
        println!(
            "contended scaling gate SKIPPED: {cores} core(s) available, need >= 4 \
             (measured {scaling:.2}x)"
        );
    } else if scaling < 2.0 {
        println!(
            "FAIL: 4-thread contended lookups only {scaling:.2}x the 1-thread rate \
             (acceptance bar: >= 2x on a {cores}-core machine)"
        );
        acceptance_failed = true;
    }
    let stats = srv.stats();
    println!(
        "server counters: {} lookups, {} snapshot hits, {} shard reads, gen {} \
         ({} publish(es))",
        stats.lookups, stats.lru_hits, stats.shard_reads, stats.snapshot_gen,
        stats.snapshot_publishes
    );

    let record = json::obj(vec![
        ("contended_1_per_s", json::num(one_per_s)),
        ("contended_4_per_s", json::num(four_per_s)),
        ("contended_scaling", json::num(scaling)),
        ("contended_gate_enforced", Json::Bool(gate_enforced)),
        ("cores", json::int(cores as i64)),
        ("transfer_miss_per_s", json::num(transfer_per_s)),
        ("lease_cycle_per_s", json::num(lease_per_s)),
        ("contended_1_latency_us", one_hist.to_json()),
        ("contended_4_latency_us", four_hist.to_json()),
        ("transfer_latency_us", transfer_hist.to_json()),
        ("lease_latency_us", lease_hist.to_json()),
        ("platforms", json::int(platforms as i64)),
    ]);
    println!("JSON: {}", record.compact());

    std::fs::remove_dir_all(&dir).ok();
    // The 2x contended-scaling ratio is an acceptance criterion, not a
    // suggestion: exit non-zero so CI fails when the read path grows a
    // lock that serializes clients (on hardware wide enough to tell).
    if acceptance_failed {
        std::process::exit(1);
    }
    Ok(())
}
