//! E5 — "A Few Fit Most" for dense GEMM: does a portfolio of K ≤ 4
//! schedule configs retain ≥ 90% of per-shape-tuned performance across
//! a shape sweep?
//!
//! Three series per shape, GFLOP/s:
//! * **per-shape tuned** — the matrix minimum for that shape (what
//!   exhaustive tuning of every shape individually delivers);
//! * **portfolio** — the config the deployed feature selector
//!   ([`Portfolio::select_for_dims`]) picks from the K-member
//!   portfolio for that shape's dims;
//! * **single default** — the naive un-tuned schedule everywhere.
//!
//! Fully hermetic (native GEMM, no XLA, no artifacts).  Also proves
//! the serving story end to end: the sweep history and the portfolio
//! are recorded into a temp shard store and an in-process [`Server`]
//! answers a `portfolio` op for the recorded platform.
//!
//! Machine-readable tail: `JSON: {...}`.  Exits non-zero when the
//! portfolio needs more than 4 configs or retains < 90% — these are
//! acceptance criteria, not suggestions.
//!
//! Run: `cargo bench --bench portfolio` (BENCH_QUICK=1 to shrink).

use portatune::coordinator::platform::Fingerprint;
use portatune::coordinator::portfolio::{sweep_gemm, sweep_measure_cfg, Portfolio};
use portatune::coordinator::selection::Tolerance;
use portatune::report::Table;
use portatune::service::{Request, ServeOpts, Server};
use portatune::util::json::{self, Json};
use portatune::workload::gemm;
use portatune::coordinator::perfdb::ShardedDb;

const K_MAX: usize = 4;
const TARGET_RETAINED: f64 = 0.9;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let shapes = if quick { gemm::quick_sweep() } else { gemm::default_sweep() };
    let host = Fingerprint::detect();
    println!(
        "portfolio bench — gemm, {} shapes x {} configs (quick={quick})",
        shapes.len(),
        gemm::configs().len()
    );

    let sweep = sweep_gemm(&shapes, &sweep_measure_cfg(quick), Tolerance::default(), 42, &host)?;
    let matrix = &sweep.matrix;
    let built = matrix.build_portfolio(K_MAX, TARGET_RETAINED)?;

    // Column index per portfolio member, for cost lookups.
    let member_col = |p: &Portfolio, config_id: &str| {
        matrix.config_ids.iter().position(|id| id == config_id).unwrap_or_else(|| {
            panic!("portfolio {} references unknown config {config_id}", p.kernel)
        })
    };

    let mut t = Table::new(&[
        "shape", "tuned cfg", "tuned", "portfolio cfg", "portfolio", "default", "retained",
    ]);
    let mut retained_selected_sum = 0.0;
    let mut retained_default_sum = 0.0;
    let gflops = |flops: u64, cost: f64| flops as f64 / cost / 1e9;
    for (s, shape) in matrix.shapes.iter().enumerate() {
        let (best_idx, best_cost) =
            matrix.best_for_shape(s).expect("every shape has a finite winner");
        let selected = built
            .select_for_dims(&shape.dims, &host)
            .expect("non-empty portfolio always selects");
        let sel_cost = matrix.costs[s][member_col(&built, &selected.config_id)];
        let default_cost = matrix.costs[s][sweep.default_index];
        let retained = best_cost / sel_cost;
        retained_selected_sum += retained;
        retained_default_sum += best_cost / default_cost;
        t.row(vec![
            shape.tag.clone(),
            matrix.config_ids[best_idx].clone(),
            format!("{:.2}", gflops(shape.flops, best_cost)),
            selected.config_id.clone(),
            format!("{:.2}", gflops(shape.flops, sel_cost)),
            format!("{:.2}", gflops(shape.flops, default_cost)),
            format!("{:.0}%", retained * 100.0),
        ]);
    }
    print!("{}", t.render());
    let nshapes = matrix.shapes.len() as f64;
    let retained_selected = retained_selected_sum / nshapes;
    let retained_default = retained_default_sum / nshapes;
    println!(
        "portfolio: {} config(s) — builder retention {:.1}%, deployed-selector retention {:.1}%, \
         single-default retention {:.1}%",
        built.len(),
        built.retained * 100.0,
        retained_selected * 100.0,
        retained_default * 100.0
    );

    // Serving story: record history + portfolio, ask the daemon core.
    let dir = std::env::temp_dir().join(format!("portatune-pfbench-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let db = ShardedDb::open(&dir)?;
    let key = host.key();
    db.record_many(&key, Some(&host), sweep.entries(&key, "sweep-exhaustive"))?;
    db.record_portfolio(&key, Some(&host), built.clone())?;
    let server = Server::new(db, host.clone(), ServeOpts::default());
    let reply = server.handle_request(&Request::Portfolio {
        platform: None, // daemon resolves to its own host key
        kernel: gemm::KERNEL.to_string(),
        dims: Some(matrix.shapes[0].dims.clone()),
        fingerprint: None,
    });
    let serve_ok = reply.get("ok").and_then(Json::as_bool) == Some(true)
        && reply.get("source").and_then(Json::as_str) == Some("exact")
        && reply.get("selected").and_then(|s| s.get("config_id")).is_some();
    println!(
        "serve: portfolio op for recorded platform -> source={} selected={}",
        reply.get("source").and_then(Json::as_str).unwrap_or("?"),
        reply
            .get("selected")
            .and_then(|s| s.get("config_id"))
            .and_then(Json::as_str)
            .unwrap_or("?")
    );
    std::fs::remove_dir_all(&dir).ok();

    let record = json::obj(vec![
        ("shapes", json::int(matrix.shapes.len() as i64)),
        ("configs", json::int(matrix.configs.len() as i64)),
        ("k", json::int(built.len() as i64)),
        ("k_max", json::int(K_MAX as i64)),
        ("retained", json::num(built.retained)),
        ("retained_selected", json::num(retained_selected)),
        ("retained_default", json::num(retained_default)),
        (
            "portfolio_over_default",
            json::num(retained_selected / retained_default.max(1e-12)),
        ),
        ("serve_portfolio_ok", Json::Bool(serve_ok)),
    ]);
    println!("JSON: {}", record.compact());

    let mut failed = false;
    if built.len() > K_MAX {
        println!("FAIL: portfolio has {} configs (cap {K_MAX})", built.len());
        failed = true;
    }
    if built.retained < TARGET_RETAINED {
        println!(
            "FAIL: portfolio retains {:.1}% of per-shape-tuned performance \
             (acceptance bar: >= {:.0}%)",
            built.retained * 100.0,
            TARGET_RETAINED * 100.0
        );
        failed = true;
    }
    if !serve_ok {
        println!("FAIL: serve daemon did not answer the portfolio op with an exact selection");
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}
