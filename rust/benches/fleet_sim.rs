//! Fleet-scale robustness gate: the deterministic simulation from
//! `portatune::sim` run at CI size, twice, with hard acceptance bars.
//!
//! One run drives the real task queue, sharded store, and transfer
//! ranking for a 1000-platform fleet drained by 8 simulated workers
//! under crash churn, fingerprint drift, and Poisson lookup traffic —
//! all on a virtual clock seeded from `FLEET_SIM_SEED` (default 4242).
//! The second run repeats the first seed and must reproduce it *bit
//! for bit*: same report, same audit-log bytes.  Gates:
//!
//! * the initial backlog converges (every initially-stale identity
//!   refreshed) before the run ends;
//! * duplicate work — executions finished after someone else already
//!   settled the task — stays ≤ 1%;
//! * staleness-at-serve percentiles are ordered and bounded by the
//!   simulated horizon (plus the ≤25% overshoot of the telemetry
//!   histogram's bucket upper bounds, which is what the sim reports);
//! * the run's audit log passes hash-chain verification (enforced
//!   inside [`portatune::sim::run`] itself) and the repeat run's log
//!   is byte-identical;
//! * the seeded mid-run slowdowns are detected by the regression
//!   sentinel (at least one confirmation, bounded detection latency)
//!   with **zero** false positives on stationary platforms;
//! * the core-hour ledger accumulated non-zero spend and benefit
//!   through the real store's write path.
//!
//! Any violation prints `FAIL: ...` and exits 1.  Machine-readable
//! tail: `JSON: {...}` (the first run's report).
//!
//! Env knobs: `BENCH_QUICK=1` shrinks to the smoke fleet;
//! `FLEET_SIM_SEED=N` picks the seed; `FLEET_SIM_DIR=path` keeps the
//! first run's shards and audit log there (instead of a temp dir) so
//! CI can run `portatune audit verify` on the evidence afterwards.
//!
//! Run: `cargo bench --bench fleet_sim`

use std::path::PathBuf;
use std::time::Instant;

use portatune::sim::{run, SimConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let seed: u64 = std::env::var("FLEET_SIM_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4242);
    let (keep_dir, root) = match std::env::var("FLEET_SIM_DIR") {
        Ok(dir) => (true, PathBuf::from(dir)),
        Err(_) => (
            false,
            std::env::temp_dir().join(format!("portatune-fleetsim-{}", std::process::id())),
        ),
    };
    std::fs::create_dir_all(&root)?;

    let cfg = |sub: &str| {
        let base = root.join(sub);
        if quick {
            SimConfig::smoke(&base, seed)
        } else {
            SimConfig::fleet(&base, seed)
        }
    };

    let cfg_a = cfg("run-a");
    println!(
        "fleet-sim bench — {} platforms, {} workers, {} sim-seconds, seed {} ({})",
        cfg_a.platforms,
        cfg_a.workers,
        cfg_a.duration_s,
        seed,
        if quick { "quick" } else { "full" },
    );

    let t0 = Instant::now();
    let report = run(&cfg_a)?;
    let wall_a = t0.elapsed().as_secs_f64();
    println!(
        "run A: {:.1}s wall — {} enqueued, {} completions, {} duplicates ({:.3}%), \
         convergence {:?}, staleness p50/p95/p99 {}/{}/{}s, {} audit entries",
        wall_a,
        report.tasks_enqueued,
        report.completions,
        report.duplicates,
        report.duplicate_rate * 100.0,
        report.convergence_s,
        report.staleness_p50_s,
        report.staleness_p95_s,
        report.staleness_p99_s,
        report.audit_entries,
    );
    println!(
        "        {} slowdown(s) injected, {} regression(s) confirmed \
         (latency mean {:.0}s / max {}s, {} false positive(s), {} undetected), \
         ledger spend {}ms / benefit {}ms",
        report.slow_platforms,
        report.regressions_detected,
        report.detection_latency_mean_s,
        report.detection_latency_max_s,
        report.regression_false_positives,
        report.slowdowns_undetected,
        report.ledger_spend_ms,
        report.ledger_benefit_ms,
    );

    // Repeat the seed: the whole decision sequence must reproduce.
    let cfg_b = cfg("run-b");
    let t1 = Instant::now();
    let repeat = run(&cfg_b)?;
    println!("run B (same seed): {:.1}s wall", t1.elapsed().as_secs_f64());

    let mut failed = false;
    let mut fail = |msg: String| {
        println!("FAIL: {msg}");
        failed = true;
    };

    if repeat != report {
        fail(format!("same seed produced a different report:\n  A: {report:?}\n  B: {repeat:?}"));
    }
    let bytes_a = std::fs::read(&cfg_a.audit_path)?;
    let bytes_b = std::fs::read(&cfg_b.audit_path)?;
    if bytes_a != bytes_b {
        fail(format!(
            "same seed produced different audit logs ({} vs {} bytes)",
            bytes_a.len(),
            bytes_b.len()
        ));
    }
    match report.convergence_s {
        Some(s) => println!("converged in {s} sim-seconds"),
        None => fail("initial backlog never converged within the run".to_string()),
    }
    if report.duplicate_rate > 0.01 {
        fail(format!(
            "duplicate-work rate {:.4} exceeds the 1% bar ({} of {} executions)",
            report.duplicate_rate, report.duplicates, report.executions
        ));
    }
    if report.staleness_p50_s > report.staleness_p95_s
        || report.staleness_p95_s > report.staleness_p99_s
    {
        fail(format!(
            "staleness percentiles out of order: p50 {} p95 {} p99 {}",
            report.staleness_p50_s, report.staleness_p95_s, report.staleness_p99_s
        ));
    }
    // The sim reports histogram bucket upper bounds, which may sit up
    // to 25% above the true percentile — the gate allows exactly that.
    let horizon = (cfg_a.ttl_s + cfg_a.duration_s) * 5 / 4;
    if report.staleness_p99_s > horizon {
        fail(format!(
            "staleness p99 {}s exceeds the simulated horizon {}s (with bucket slack)",
            report.staleness_p99_s, horizon
        ));
    }
    if report.serves == 0 || report.exact_hits == 0 {
        fail(format!(
            "traffic produced no serves ({}) or no exact hits ({})",
            report.serves, report.exact_hits
        ));
    }
    if report.slow_platforms > 0 && report.regressions_detected == 0 {
        fail(format!(
            "{} seeded slowdown(s), zero sentinel confirmations",
            report.slow_platforms
        ));
    }
    if report.regression_false_positives != 0 {
        fail(format!(
            "{} regression false positive(s) — stationary noise must never fire",
            report.regression_false_positives
        ));
    }
    // Detection must land within a handful of telemetry windows of the
    // injection: 5-sample confirmation × cadence, with slack for one
    // refresh-polluted window.
    let latency_bar = cfg_a.telemetry_every_s * 10;
    if report.regressions_detected > 0
        && (report.detection_latency_max_s == 0 || report.detection_latency_max_s > latency_bar)
    {
        fail(format!(
            "detection latency {}s outside (0, {latency_bar}]s",
            report.detection_latency_max_s
        ));
    }
    if report.ledger_spend_ms == 0 || report.ledger_benefit_ms == 0 {
        fail(format!(
            "ledger never accrued (spend {}ms, benefit {}ms)",
            report.ledger_spend_ms, report.ledger_benefit_ms
        ));
    }

    // Run B was only evidence for the determinism gate; run A's dir is
    // what CI verifies with `portatune audit verify`.
    std::fs::remove_dir_all(root.join("run-b")).ok();
    if keep_dir {
        println!("kept evidence: {} (audit log + shards)", cfg_a.audit_path.display());
    } else {
        std::fs::remove_dir_all(&root).ok();
    }

    println!("JSON: {}", report.to_json().compact());
    if failed {
        std::process::exit(1);
    }
    Ok(())
}
