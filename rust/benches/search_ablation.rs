//! A1: search-strategy ablation (the Orio strategy set).
//!
//! All five strategies search the same real variant space (axpy on a
//! 1M-element workload, 12 valid points; stencil2d 512^2, 20 points)
//! under shrinking budgets.  Reported per (strategy, budget): best-found
//! cost relative to the exhaustive optimum, and unique evaluations
//! spent.  Expected shape: exhaustive is optimal by construction;
//! anneal/GA/hillclimb reach within a few percent on ~1/3 of the
//! budget; random needs more.  Measurements reuse the compile cache, so
//! each variant is compiled once across the whole ablation.
//!
//! Run: `cargo bench --bench search_ablation` (BENCH_QUICK=1 to shrink).

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::{
    Anneal, Exhaustive, Genetic, HillClimb, RandomSearch, SearchStrategy,
};
use portatune::coordinator::tuner::Tuner;
use portatune::report::Table;
use portatune::runtime::{Registry, Runtime};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    tuner.measure_cfg = MeasureConfig::quick(); // schedule ranking, not absolutes

    let cases: &[(&str, &str)] = if quick {
        &[("axpy", "n16384")]
    } else {
        // Sizes chosen so even the slowest variant runs in milliseconds:
        // the ablation needs many tune() calls and measures *rankings*,
        // not absolute times.
        &[("axpy", "n65536"), ("stencil2d", "m256_n256")]
    };

    println!("experiment A1 — search strategy ablation (Orio strategy set)");
    println!("quality = best-found / exhaustive optimum (1.00 = optimal)\n");

    for (kernel, tag) in cases {
        // Ground truth via exhaustive.
        let mut ex = Exhaustive::new();
        let truth = tuner.tune(kernel, tag, &mut ex, usize::MAX)?;
        let optimum = truth.best.as_ref().unwrap().cost;
        let space = truth.evaluations();
        println!(
            "{kernel}/{tag}: {space} valid variants, optimum {:.3} ms ({})",
            optimum * 1e3,
            truth.best.as_ref().unwrap().config_id
        );

        let budgets = [space / 4, space / 3, space / 2, space];
        let mut t = Table::new(&["strategy", "budget", "evals", "best", "quality"]);
        for &budget in &budgets {
            let budget = budget.max(2);
            let strategies: Vec<Box<dyn SearchStrategy>> = vec![
                Box::new(Exhaustive::new()),
                Box::new(RandomSearch::new(7)),
                Box::new(HillClimb::new(7)),
                Box::new(Anneal::new(7)),
                Box::new(Genetic::new(7)),
            ];
            for mut s in strategies {
                let outcome = tuner.tune(kernel, tag, s.as_mut(), budget)?;
                // Exclude the forced default eval from the budget view.
                let best = outcome.best.as_ref().unwrap().cost;
                t.row(vec![
                    s.name().to_string(),
                    budget.to_string(),
                    outcome.evaluations().to_string(),
                    format!("{:.3} ms", best * 1e3),
                    format!("{:.2}", best / optimum),
                ]);
            }
            eprint!(".");
        }
        eprintln!();
        print!("{}", t.render());
        println!();
    }
    println!("note: every strategy also gets the forced default-schedule");
    println!("evaluation (Figure 1's baseline), so `evals` can be budget+1.");
    Ok(())
}
