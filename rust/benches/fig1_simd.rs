//! E1 / Figure 1: auto-vectorized (un-annotated baseline) vs autotuned
//! SIMD-loop kernels across input vector sizes.  Regenerates the paper's
//! figure (time series + speedup bars) for axpy, dot, and triad, with
//! the XLA reference as the vendor-comparator column.
//!
//! Run: `cargo bench --bench fig1_simd` (BENCH_QUICK=1 for a smoke run).

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::report::{Fig1Report, Fig1Row};
use portatune::runtime::{Registry, Runtime};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    tuner.measure_cfg = if quick {
        MeasureConfig::quick()
    } else {
        MeasureConfig { warmup: 1, reps: 3, target_rel_spread: 0.5, max_reps: 4, outlier_k: 5.0, ..MeasureConfig::default() }
    };

    println!("experiment E1 (paper Figure 1) — SIMD vector kernels");
    println!("baseline = un-annotated default schedule (b1024_u1); autotuned = best");
    println!("of the pre-lowered variant space; xla-ref = pure-XLA lowering\n");

    for kernel in ["axpy", "dot", "triad"] {
        let entry = registry.manifest().kernel(kernel).unwrap().clone();
        let mut report = Fig1Report::new(kernel);
        for w in &entry.workloads {
            let cap = if quick { 262144 } else { 1048576 };
            if w.dims["n"] > cap {
                continue;
            }
            let mut strategy = Exhaustive::new();
            let outcome = tuner.tune(kernel, &w.tag, &mut strategy, usize::MAX)?;
            report.push(Fig1Row {
                size: w.tag.clone(),
                baseline_s: outcome.baseline_time(),
                reference_s: outcome.reference.cost(),
                tuned_s: outcome.best_time(),
                best_id: outcome
                    .best
                    .as_ref()
                    .map(|b| b.config_id.clone())
                    .unwrap_or_else(|| "baseline".into()),
                evaluations: outcome.evaluations(),
            });
            eprint!(".");
        }
        eprintln!();
        println!("{}", report.render());
    }
    Ok(())
}
