//! A2: tuning-overhead amortization — the paper's core-hours economics —
//! plus the batched-pipeline ablation: serial (compile → gate → full
//! measurement, one variant at a time) vs batched (background compile
//! prefetch + interleaved racing with early termination).
//!
//! Reported per workload and pipeline: tuning wall clock, compile time
//! attributable to the tune (batched mode sums across prefetch threads,
//! so compile_ms > wall-share demonstrates real overlap), timed
//! repetitions spent and saved by the cutoff, and the break-even run
//! count — how many production runs repay the tuning investment.  The
//! batched pipeline must select the same winner as serial full
//! measurement; the bench prints a loud warning if it ever does not.
//!
//! Machine-readable trajectory: the final line prints `JSON: [...]` with
//! one record per (workload, pipeline), including the full TuneStats.
//!
//! Run: `cargo bench --bench overhead` (BENCH_QUICK=1 to shrink).

use std::time::Instant;

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::{TuneOutcome, Tuner};
use portatune::report::{outcome_json, Table};
use portatune::runtime::Registry;
use portatune::runtime::Runtime;
use portatune::util::json::Json;

const RACE_BATCH: usize = 4;

fn record(outcome: &TuneOutcome, pipeline: &str, wall_s: f64) -> Json {
    let Json::Obj(mut obj) = outcome_json(outcome) else {
        unreachable!("outcome_json is always an object");
    };
    obj.insert("pipeline".to_string(), Json::Str(pipeline.to_string()));
    obj.insert("wall_s".to_string(), Json::Num(wall_s));
    Json::Obj(obj)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let measure_cfg = if quick {
        MeasureConfig::quick()
    } else {
        MeasureConfig {
            warmup: 1,
            reps: 7,
            target_rel_spread: 0.5,
            max_reps: 7,
            ..MeasureConfig::default()
        }
    };

    let cases: &[(&str, &str)] = if quick {
        &[("axpy", "n262144")]
    } else {
        &[("axpy", "n262144"), ("jacobi", "m256_n256"), ("spmv_ell", "k32_nrows16384")]
    };

    println!("experiment A2 — tuning-cost amortization + batched-pipeline savings");
    println!("tuning cost includes every variant's XLA compilation + measurement\n");

    let mut t = Table::new(&[
        "workload", "pipeline", "tune cost", "compile", "measure", "compiles",
        "reps timed", "reps saved", "default/run", "tuned/run", "break-even",
    ]);
    let mut records: Vec<Json> = Vec::new();
    for (kernel, tag) in cases {
        let mut serial_winner: Option<String> = None;
        let mut serial_reps: u64 = 0;
        for (pipeline, batch) in [("serial", 1usize), ("batched", RACE_BATCH)] {
            // Cold-start: drop the compile cache so the tuning cost is
            // honest (first tune on a fresh platform).
            registry.clear_cache();
            let mut tuner = Tuner::new(&registry).with_batch(batch);
            tuner.measure_cfg = measure_cfg.clone();
            let mut strategy = Exhaustive::new();
            let t0 = Instant::now();
            let outcome = tuner.tune(kernel, tag, &mut strategy, usize::MAX)?;
            let wall = t0.elapsed().as_secs_f64();

            let winner = outcome
                .best
                .as_ref()
                .map(|b| b.config_id.clone())
                .unwrap_or_else(|| "baseline".into());
            match pipeline {
                "serial" => {
                    serial_winner = Some(winner.clone());
                    serial_reps = outcome.stats.reps_timed;
                }
                _ => {
                    if serial_winner.as_deref() != Some(winner.as_str()) {
                        println!(
                            "WARNING: {kernel}/{tag} batched winner {winner} != serial {:?}",
                            serial_winner
                        );
                    }
                    if serial_reps > 0 {
                        let cut = 100.0
                            * (1.0 - outcome.stats.reps_timed as f64 / serial_reps as f64);
                        println!(
                            "{kernel}/{tag}: batched pipeline spent {:.0}% fewer timed reps \
                             ({} vs {serial_reps}), same winner = {}",
                            cut,
                            outcome.stats.reps_timed,
                            serial_winner.as_deref() == Some(winner.as_str()),
                        );
                    }
                }
            }

            let default_run = outcome.baseline_time();
            let tuned_run = outcome.best_time();
            let saving = default_run - tuned_run;
            let break_even = if saving > 0.0 {
                format!("{:.0}", (wall / saving).ceil())
            } else {
                "-".to_string()
            };
            t.row(vec![
                format!("{kernel}/{tag}"),
                pipeline.to_string(),
                format!("{:.2} s", wall),
                format!("{:.0} ms", outcome.stats.compile_ms),
                format!("{:.0} ms", outcome.stats.measure_ms),
                outcome.stats.compiles.to_string(),
                outcome.stats.reps_timed.to_string(),
                outcome.stats.reps_saved.to_string(),
                format!("{:.3} ms", default_run * 1e3),
                format!("{:.3} ms", tuned_run * 1e3),
                break_even,
            ]);
            records.push(record(&outcome, pipeline, wall));
            eprint!(".");
        }
    }
    eprintln!();
    print!("{}", t.render());
    println!("\nbreak-even = tuning cost / per-run saving: a long-running solver");
    println!("(thousands of kernel invocations per job) repays tuning within its");
    println!("first job; the perf DB then amortizes it across the whole fleet.");
    println!("batched compile_ms can exceed its share of wall time: that surplus");
    println!("is compilation overlapped onto background threads.");
    println!("\nJSON: {}", Json::Arr(records).compact());
    Ok(())
}
