//! A2: tuning-overhead amortization — the paper's core-hours economics.
//!
//! The intro's motivation: supercomputing allocations pay for every
//! un-tuned run.  This bench measures (a) the one-time cost of tuning a
//! workload (wall clock, including every XLA variant compilation) and
//! (b) the per-run saving of the tuned schedule vs the un-annotated
//! default, and reports the break-even run count — how many production
//! runs repay the tuning investment.  With the perf DB the investment is
//! paid once per platform, not once per user (see examples/portability).
//!
//! Run: `cargo bench --bench overhead` (BENCH_QUICK=1 to shrink).

use std::time::Instant;

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::{Anneal, Exhaustive, SearchStrategy};
use portatune::coordinator::tuner::Tuner;
use portatune::report::Table;
use portatune::runtime::{Registry, Runtime};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    tuner.measure_cfg = if quick {
        MeasureConfig::quick()
    } else {
        MeasureConfig { warmup: 1, reps: 3, target_rel_spread: 0.5, max_reps: 3, outlier_k: 5.0 }
    };

    let cases: &[(&str, &str)] = if quick {
        &[("axpy", "n262144")]
    } else {
        &[("axpy", "n262144"), ("jacobi", "m256_n256"), ("spmv_ell", "k32_nrows16384")]
    };

    println!("experiment A2 — tuning-cost amortization (core-hours argument)");
    println!("tuning cost includes every variant's XLA compilation + measurement\n");

    let mut t = Table::new(&[
        "workload", "strategy", "tune cost", "compiles", "default/run",
        "tuned/run", "saving/run", "break-even runs",
    ]);
    for (kernel, tag) in cases {
        for (sname, mut strategy) in [
            ("exhaustive", Box::new(Exhaustive::new()) as Box<dyn SearchStrategy>),
            ("anneal", Box::new(Anneal::new(11)) as Box<dyn SearchStrategy>),
        ] {
            // Cold-start: drop the compile cache so the tuning cost is
            // honest (first tune on a fresh platform).
            registry.clear_cache();
            let compiles_before = registry.compile_count();
            let t0 = Instant::now();
            let budget = if sname == "anneal" { 8 } else { usize::MAX };
            let outcome = tuner.tune(kernel, tag, strategy.as_mut(), budget)?;
            let tune_cost = t0.elapsed().as_secs_f64();
            let compiles = registry.compile_count() - compiles_before;

            let default_run = outcome.baseline_time();
            let tuned_run = outcome.best_time();
            let saving = default_run - tuned_run;
            let break_even = if saving > 0.0 {
                format!("{:.0}", (tune_cost / saving).ceil())
            } else {
                "-".to_string()
            };
            t.row(vec![
                format!("{kernel}/{tag}"),
                sname.to_string(),
                format!("{:.2} s", tune_cost),
                compiles.to_string(),
                format!("{:.3} ms", default_run * 1e3),
                format!("{:.3} ms", tuned_run * 1e3),
                format!("{:.3} ms", saving * 1e3),
                break_even,
            ]);
            eprint!(".");
        }
    }
    eprintln!();
    print!("{}", t.render());
    println!("\nbreak-even = tuning cost / per-run saving: a long-running solver");
    println!("(thousands of kernel invocations per job) repays tuning within its");
    println!("first job; the perf DB then amortizes it across the whole fleet.");
    Ok(())
}
