//! E3 (ref [1] analog): ELLPACK SpMV autotuning.  The GPU paper beat
//! cuSPARSE/CUSP with autotuned stencil-aware kernels; here the tuned
//! row-block x col-chunk schedule is compared against the un-annotated
//! default and XLA's own lowering of the same ELL computation.
//!
//! Run: `cargo bench --bench spmv` (BENCH_QUICK=1 for a smoke run).

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::report::Table;
use portatune::runtime::{Registry, Runtime};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    tuner.measure_cfg = if quick {
        MeasureConfig::quick()
    } else {
        MeasureConfig { warmup: 1, reps: 3, target_rel_spread: 0.5, max_reps: 4, outlier_k: 5.0, ..MeasureConfig::default() }
    };

    println!("experiment E3 — ELLPACK SpMV (banded matrices, k=32 padded width)");
    println!("baseline = default schedule rb256_cc32\n");

    let entry = registry.manifest().kernel("spmv_ell").unwrap().clone();
    let mut t = Table::new(&[
        "matrix", "baseline", "autotuned", "best", "speedup", "xla-ref", "vs-ref",
        "GiB/s",
    ]);
    for w in &entry.workloads {
        if quick && w.dims["nrows"] > 16384 {
            continue;
        }
        let mut strategy = Exhaustive::new();
        let outcome = tuner.tune("spmv_ell", &w.tag, &mut strategy, usize::MAX)?;
        let best = outcome.best.as_ref().unwrap();
        t.row(vec![
            w.tag.clone(),
            format!("{:.3} ms", outcome.baseline_time() * 1e3),
            format!("{:.3} ms", outcome.best_time() * 1e3),
            best.config_id.clone(),
            format!("{:.2}x", outcome.speedup()),
            format!("{:.3} ms", outcome.reference.cost() * 1e3),
            format!("{:.2}", outcome.vs_reference()),
            format!(
                "{:.2}",
                best.measurement.as_ref().map(|m| m.gibps(outcome.bytes)).unwrap_or(0.0)
            ),
        ]);
        eprint!(".");
    }
    eprintln!();
    print!("{}", t.render());

    // Matmul rides along as the dense-kernel counterpart (MXU study).
    println!("\ndense counterpart — blocked GEMM tile autotuning");
    let entry = registry.manifest().kernel("matmul").unwrap().clone();
    let mut t = Table::new(&[
        "size", "baseline", "autotuned", "best tile", "speedup", "xla-ref",
        "vs-ref", "GFLOP/s",
    ]);
    for w in &entry.workloads {
        if quick && w.dims["m"] > 256 {
            continue;
        }
        let mut strategy = Exhaustive::new();
        let outcome = tuner.tune("matmul", &w.tag, &mut strategy, usize::MAX)?;
        let best = outcome.best.as_ref().unwrap();
        t.row(vec![
            w.tag.clone(),
            format!("{:.3} ms", outcome.baseline_time() * 1e3),
            format!("{:.3} ms", outcome.best_time() * 1e3),
            best.config_id.clone(),
            format!("{:.2}x", outcome.speedup()),
            format!("{:.3} ms", outcome.reference.cost() * 1e3),
            format!("{:.2}", outcome.vs_reference()),
            format!(
                "{:.2}",
                best.measurement.as_ref().map(|m| m.gflops(outcome.flops)).unwrap_or(0.0)
            ),
        ]);
        eprint!(".");
    }
    eprintln!();
    print!("{}", t.render());
    Ok(())
}
