//! E2 (ref [2] analog): stencil tile autotuning across grid sizes.
//! The GPU paper tuned threadblock shapes for iterative stencil solvers;
//! here the 2-D Pallas tile space plays that role.
//!
//! Run: `cargo bench --bench stencil` (BENCH_QUICK=1 for a smoke run).

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::report::Table;
use portatune::runtime::{Registry, Runtime};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    tuner.measure_cfg = if quick {
        MeasureConfig::quick()
    } else {
        MeasureConfig { warmup: 1, reps: 3, target_rel_spread: 0.5, max_reps: 4, outlier_k: 5.0, ..MeasureConfig::default() }
    };

    println!("experiment E2 — stencil2d (5-point Jacobi) tile autotuning");
    println!("baseline = default tile tm32_tn32; 16-20 valid tiles per size\n");

    let entry = registry.manifest().kernel("stencil2d").unwrap().clone();
    let mut t = Table::new(&[
        "grid", "baseline", "autotuned", "best tile", "speedup", "xla-ref",
        "vs-ref", "evals", "GiB/s",
    ]);
    for w in &entry.workloads {
        let cap = if quick { 256 } else { 512 };
        if w.dims["m"] > cap {
            // 1024^2 with 8-wide tiles hits the un-aliased-loop pathology
            // (DESIGN.md §8): tunable via the CLI, skipped in the sweep.
            continue;
        }
        let mut strategy = Exhaustive::new();
        let outcome = tuner.tune("stencil2d", &w.tag, &mut strategy, usize::MAX)?;
        let best = outcome.best.as_ref().unwrap();
        t.row(vec![
            w.tag.clone(),
            format!("{:.3} ms", outcome.baseline_time() * 1e3),
            format!("{:.3} ms", outcome.best_time() * 1e3),
            best.config_id.clone(),
            format!("{:.2}x", outcome.speedup()),
            format!("{:.3} ms", outcome.reference.cost() * 1e3),
            format!("{:.2}", outcome.vs_reference()),
            outcome.evaluations().to_string(),
            format!(
                "{:.2}",
                best.measurement.as_ref().map(|m| m.gibps(outcome.bytes)).unwrap_or(0.0)
            ),
        ]);
        eprint!(".");
    }
    eprintln!();
    print!("{}", t.render());
    Ok(())
}
